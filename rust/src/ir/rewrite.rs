//! Expression rewriting (paper §3.4.1, Fig. 10).
//!
//! The headline rewrite uses associativity/distributivity to *factorize
//! contractions*: a contraction over an outer-product chain
//! `S # S # S # u . [[1 6][3 7][5 8]]` (cost O(p^9) if materialized)
//! becomes a chain of n-mode products (GEMMs), cost O(3·2·p^4). This is
//! the transformation shown in Fig. 7b and Fig. 10, and it is what makes
//! the operator implementable as pipelined loop nests.
//!
//! The rewriter is strictly semantics-preserving: it recognizes the
//! contraction-over-product pattern, checks the mode conditions that make
//! the ModeApply chain exactly equivalent, and falls back to the naive
//! diag/red lowering otherwise. Equivalence is property-tested against
//! the teil interpreter on random tensors.

use std::collections::HashMap;

use super::teil::{Def, Module, Op, ValId};

/// Run all rewrites: contraction factorization + dead-value elimination.
pub fn optimize(m: Module) -> Module {
    let mut out = Module {
        values: Vec::new(),
        defs: Vec::new(),
        inputs: m.inputs.clone(),
    };
    let mut memo: HashMap<ValId, ValId> = HashMap::new();
    let defs = m.defs.clone();
    for d in &defs {
        let nv = emit(&m, d.value, &mut out, &mut memo);
        out.defs.push(Def {
            name: d.name.clone(),
            value: nv,
            is_output: d.is_output,
        });
    }
    out
}

/// Recursively emit `v` into `out`, applying rewrites at each node.
fn emit(
    m: &Module,
    v: ValId,
    out: &mut Module,
    memo: &mut HashMap<ValId, ValId>,
) -> ValId {
    if let Some(&nv) = memo.get(&v) {
        return nv;
    }
    let nv = if let Some(chain) = match_contraction(m, v) {
        emit_mode_chain(m, &chain, out, memo)
    } else {
        // structural re-emit
        let op = match &m.values[v].op {
            Op::Arg { name } => Op::Arg { name: name.clone() },
            Op::Prod { a, b } => Op::Prod {
                a: emit(m, *a, out, memo),
                b: emit(m, *b, out, memo),
            },
            Op::Diag { x, i, j } => Op::Diag {
                x: emit(m, *x, out, memo),
                i: *i,
                j: *j,
            },
            Op::Red { x, axis } => Op::Red {
                x: emit(m, *x, out, memo),
                axis: *axis,
            },
            Op::Add { a, b } => Op::Add {
                a: emit(m, *a, out, memo),
                b: emit(m, *b, out, memo),
            },
            Op::Sub { a, b } => Op::Sub {
                a: emit(m, *a, out, memo),
                b: emit(m, *b, out, memo),
            },
            Op::Mul { a, b } => Op::Mul {
                a: emit(m, *a, out, memo),
                b: emit(m, *b, out, memo),
            },
            Op::Div { a, b } => Op::Div {
                a: emit(m, *a, out, memo),
                b: emit(m, *b, out, memo),
            },
            Op::ModeApply {
                m: mat,
                x,
                mode,
                transpose,
            } => Op::ModeApply {
                m: emit(m, *mat, out, memo),
                x: emit(m, *x, out, memo),
                mode: *mode,
                transpose: *transpose,
            },
            Op::MoveAxis { x, from, to } => Op::MoveAxis {
                x: emit(m, *x, out, memo),
                from: *from,
                to: *to,
            },
            // indirection passes through untouched: the contraction
            // matcher only fires on Red(Diag(..)) trees, and a gather /
            // scatter is opaque to factorization
            Op::Gather { x, idx } => Op::Gather {
                x: emit(m, *x, out, memo),
                idx: emit(m, *idx, out, memo),
            },
            Op::Scatter { x, idx, rows, add } => Op::Scatter {
                x: emit(m, *x, out, memo),
                idx: emit(m, *idx, out, memo),
                rows: *rows,
                add: *add,
            },
        };
        let is_arg = matches!(op, Op::Arg { .. });
        let id = out.push(op).expect("re-emit of verified op");
        if is_arg {
            out.values[id].shape = m.values[v].shape.clone();
        }
        id
    };
    memo.insert(v, nv);
    nv
}

/// A recognized factorizable contraction.
struct ModeChain {
    /// The tensor factor (old ValId).
    tensor: ValId,
    /// Per contracted mode, in increasing mode order:
    /// (matrix old ValId, transpose, contracted mode).
    steps: Vec<(ValId, bool, usize)>,
    /// Axis moves to restore the contraction's global axis order
    /// (non-prefix single-mode case), applied after the mode products.
    moves: Vec<(usize, usize)>,
}

/// Recognize `Red(Diag(..Prod chain..))` trees produced by the Contract
/// lowering, in the factorizable form (see module docs).
fn match_contraction(m: &Module, v: ValId) -> Option<ModeChain> {
    // 1. Walk up the alternating Red/Diag chain, recovering the original
    //    (pre-removal) axis pairs of the base product value.
    let mut pairs_applied: Vec<(usize, usize)> = Vec::new(); // current axes
    let mut cur = v;
    loop {
        match &m.values[cur].op {
            Op::Red { x, axis } => match &m.values[*x].op {
                Op::Diag { x: base, i, j } if i == axis => {
                    pairs_applied.push((*i, *j));
                    cur = *base;
                }
                _ => return None,
            },
            _ => break,
        }
    }
    if pairs_applied.is_empty() {
        return None;
    }
    // pairs were applied innermost-first in from_ast order; reverse to
    // application order and undo the axis shifts to recover base axes.
    pairs_applied.reverse();
    let base = cur;
    let base_rank = m.shape(base).len();
    let mut axis_map: Vec<usize> = (0..base_rank).collect();
    let mut orig_pairs = Vec::new();
    for (i, j) in pairs_applied {
        if i >= axis_map.len() || j >= axis_map.len() {
            return None;
        }
        orig_pairs.push((axis_map[i], axis_map[j]));
        axis_map.remove(j);
        axis_map.remove(i); // i < j, so i's position unchanged by the first remove
    }

    // 2. Flatten the product chain (left-associative Prod tree).
    let mut factors = Vec::new();
    flatten_prod(m, base, &mut factors);
    if factors.len() < 2 {
        return None;
    }
    // axis offset of every factor in the product's global index space
    let mut offsets = Vec::with_capacity(factors.len());
    let mut off = 0;
    for &fv in &factors {
        offsets.push(off);
        off += m.shape(fv).len();
    }
    let factor_of = |axis: usize| -> usize {
        (0..factors.len())
            .rev()
            .find(|&k| offsets[k] <= axis)
            .unwrap()
    };

    // 3. Identify the single tensor factor and the rank-2 matrix factors.
    //    Every pair must connect one matrix axis to one tensor axis.
    let tensor_idx = factors.len() - 1;
    let tensor = factors[tensor_idx];
    if factors[..tensor_idx]
        .iter()
        .any(|&f| m.shape(f).len() != 2)
    {
        return None;
    }
    let t_off = offsets[tensor_idx];
    let t_rank = m.shape(tensor).len();

    // per contracted tensor mode: (matrix factor index, transpose)
    let mut steps_by_mode: Vec<Option<(usize, bool)>> = vec![None; t_rank];
    let mut used_matrix = vec![false; tensor_idx];
    for &(a, b) in &orig_pairs {
        let (ma, ta) = if factor_of(a) == tensor_idx {
            (b, a)
        } else if factor_of(b) == tensor_idx {
            (a, b)
        } else {
            return None; // matrix-matrix contraction: not this pattern
        };
        let mf = factor_of(ma);
        if mf == tensor_idx || used_matrix[mf] {
            return None;
        }
        used_matrix[mf] = true;
        let matrix_axis = ma - offsets[mf]; // 0 = rows contracted -> transpose
        let mode = ta - t_off;
        if steps_by_mode[mode].is_some() {
            return None;
        }
        steps_by_mode[mode] = Some((mf, matrix_axis == 0));
    }
    // Every matrix factor must be consumed by some pair (else it stays an
    // outer product — not a pure mode chain).
    if !used_matrix.iter().all(|&u| u) {
        return None;
    }
    // Axis-order conditions. The contraction's result axes are the
    // remaining global axes in order: matrix free axes (factor order)
    // then the tensor's free axes. Two recognized cases reproduce that
    // order with mode products:
    //
    //  (a) prefix case — contracted modes are exactly 0..k and matrix k
    //      contracts mode k: the ModeApply chain output order matches.
    //  (b) single-pair case — one matrix contracting mode m: the output
    //      is moveaxis(result, m, 0).
    let k = orig_pairs.len();
    let contracted: Vec<usize> = steps_by_mode
        .iter()
        .enumerate()
        .filter_map(|(mode, s)| s.map(|_| mode))
        .collect();
    let is_prefix = contracted.iter().copied().eq(0..k);
    if is_prefix {
        // Matrices must appear in factor order matching increasing mode,
        // otherwise the contraction's output axis order (matrix free
        // axes in *factor* order) diverges from the mode-chain order.
        let mut steps: Vec<(ValId, bool, usize)> = Vec::with_capacity(k);
        let mut prev_mf = None;
        for (mode, s) in steps_by_mode.iter().take(k).enumerate() {
            let (mf, tr) = s.expect("prefix checked");
            if let Some(prev) = prev_mf {
                if mf < prev {
                    return None;
                }
            }
            prev_mf = Some(mf);
            steps.push((factors[mf], tr, mode));
        }
        return Some(ModeChain {
            tensor,
            steps,
            moves: vec![],
        });
    }
    if k == 1 {
        let mode = contracted[0];
        let (mf, tr) = steps_by_mode[mode].expect("k == 1");
        return Some(ModeChain {
            tensor,
            steps: vec![(factors[mf], tr, mode)],
            moves: vec![(mode, 0)],
        });
    }
    None
}

fn flatten_prod(m: &Module, v: ValId, out: &mut Vec<ValId>) {
    match &m.values[v].op {
        Op::Prod { a, b } => {
            flatten_prod(m, *a, out);
            flatten_prod(m, *b, out);
        }
        _ => out.push(v),
    }
}

fn emit_mode_chain(
    m: &Module,
    chain: &ModeChain,
    out: &mut Module,
    memo: &mut HashMap<ValId, ValId>,
) -> ValId {
    let mut cur = emit(m, chain.tensor, out, memo);
    for &(mat, transpose, mode) in &chain.steps {
        let nm = emit(m, mat, out, memo);
        cur = out
            .push(Op::ModeApply {
                m: nm,
                x: cur,
                mode,
                transpose,
            })
            .expect("mode chain shapes verified by matcher");
    }
    for &(from, to) in &chain.moves {
        cur = out
            .push(Op::MoveAxis { x: cur, from, to })
            .expect("move axis in range");
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::teil;
    use crate::util::prng::Prng;
    use crate::util::prop;
    use crate::util::tensor::Tensor;
    use std::collections::HashMap as Map;

    fn eval_both(src: &str, inputs: &Map<String, Tensor>) -> (Map<String, Tensor>, Map<String, Tensor>) {
        let prog = dsl::parse(src).unwrap();
        let naive = teil::from_ast(&prog).unwrap();
        let opt = optimize(naive.clone());
        (
            teil::eval(&naive, inputs).unwrap(),
            teil::eval(&opt, inputs).unwrap(),
        )
    }

    #[test]
    fn helmholtz_is_fully_factorized() {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let m = optimize(teil::from_ast(&prog).unwrap());
        let n_mode = m
            .values
            .iter()
            .filter(|v| matches!(v.op, Op::ModeApply { .. }))
            .count();
        let n_naive = m
            .values
            .iter()
            .filter(|v| matches!(v.op, Op::Prod { .. } | Op::Diag { .. } | Op::Red { .. }))
            .count();
        assert_eq!(n_mode, 6, "3 modes for t + 3 modes for v");
        assert_eq!(n_naive, 0, "no naive contraction remnants");
    }

    #[test]
    fn factorization_reduces_cost_by_orders_of_magnitude() {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(11)).unwrap();
        let naive = teil::from_ast(&prog).unwrap();
        let opt = optimize(naive.clone());
        assert_eq!(opt.flops(), 177_023); // paper Eq. 2
        assert!(
            naive.flops() > 10_000 * opt.flops(),
            "naive {} vs optimized {}",
            naive.flops(),
            opt.flops()
        );
    }

    #[test]
    fn helmholtz_rewrite_preserves_semantics() {
        prop::check("helmholtz rewrite semantics", 12, |rng| {
            let p = rng.range_usize(2, 5);
            let src = dsl::inverse_helmholtz_source(p);
            let mut inputs = Map::new();
            inputs.insert("S".into(), Tensor::random(&[p, p], rng));
            inputs.insert("D".into(), Tensor::random(&[p, p, p], rng));
            inputs.insert("u".into(), Tensor::random(&[p, p, p], rng));
            let (naive, opt) = eval_both(&src, &inputs);
            prop::all_close(naive["v"].data(), opt["v"].data(), 1e-10)
        });
    }

    #[test]
    fn transposed_contraction_uses_transpose_flag() {
        // v-statement pairs contract S's FIRST index -> S^T mode products
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(4)).unwrap();
        let m = optimize(teil::from_ast(&prog).unwrap());
        let transposed = m
            .values
            .iter()
            .filter(|v| matches!(v.op, Op::ModeApply { transpose: true, .. }))
            .count();
        let straight = m
            .values
            .iter()
            .filter(|v| matches!(v.op, Op::ModeApply { transpose: false, .. }))
            .count();
        assert_eq!(transposed, 3);
        assert_eq!(straight, 3);
    }

    #[test]
    fn gradient_rewrite_preserves_semantics() {
        prop::check("gradient rewrite semantics", 10, |rng| {
            let (nx, ny, nz) = (
                rng.range_usize(2, 5),
                rng.range_usize(2, 5),
                rng.range_usize(2, 5),
            );
            let src = dsl::gradient_source(nx, ny, nz);
            let mut inputs = Map::new();
            inputs.insert("Dx".into(), Tensor::random(&[nx, nx], rng));
            inputs.insert("Dy".into(), Tensor::random(&[ny, ny], rng));
            inputs.insert("Dz".into(), Tensor::random(&[nz, nz], rng));
            inputs.insert("u".into(), Tensor::random(&[nx, ny, nz], rng));
            let (naive, opt) = eval_both(&src, &inputs);
            for k in ["gx", "gy", "gz"] {
                prop::all_close(naive[k].data(), opt[k].data(), 1e-10)?;
            }
            Ok(())
        });
    }

    #[test]
    fn interpolation_rewrite_preserves_semantics_nonsquare() {
        prop::check("interpolation rewrite", 8, |rng| {
            let m_ = rng.range_usize(2, 5);
            let n = rng.range_usize(2, 5);
            let src = dsl::interpolation_source(m_, n);
            let mut inputs = Map::new();
            inputs.insert("A".into(), Tensor::random(&[m_, n], rng));
            inputs.insert("u".into(), Tensor::random(&[n, n, n], rng));
            let (naive, opt) = eval_both(&src, &inputs);
            prop::all_close(naive["w"].data(), opt["w"].data(), 1e-10)
        });
    }

    #[test]
    fn gradient_rewrites_all_modes_with_axis_moves() {
        // gy/gz contract a non-prefix mode — rewritten to ModeApply plus
        // a MoveAxis restoring the contraction's global axis order.
        let prog = dsl::parse(&dsl::gradient_source(3, 4, 5)).unwrap();
        let m = optimize(teil::from_ast(&prog).unwrap());
        let modes = m
            .values
            .iter()
            .filter(|v| matches!(v.op, Op::ModeApply { .. }))
            .count();
        let moves = m
            .values
            .iter()
            .filter(|v| matches!(v.op, Op::MoveAxis { .. }))
            .count();
        let naive = m
            .values
            .iter()
            .filter(|v| {
                matches!(v.op, Op::Prod { .. } | Op::Diag { .. } | Op::Red { .. })
            })
            .count();
        assert_eq!(modes, 3);
        assert_eq!(moves, 2, "gy and gz need an axis move; gx does not");
        assert_eq!(naive, 0);
    }

    #[test]
    fn non_contractions_pass_through() {
        let src = "var input a : [3]\nvar input b : [3]\nvar output c : [3]\nc = a + b * a";
        let prog = dsl::parse(src).unwrap();
        let naive = teil::from_ast(&prog).unwrap();
        let opt = optimize(naive.clone());
        let mut rng = Prng::new(1);
        let mut inputs = Map::new();
        inputs.insert("a".into(), Tensor::random(&[3], &mut rng));
        inputs.insert("b".into(), Tensor::random(&[3], &mut rng));
        let e1 = teil::eval(&naive, &inputs).unwrap();
        let e2 = teil::eval(&opt, &inputs).unwrap();
        assert!(e1["c"].max_abs_diff(&e2["c"]) < 1e-15);
    }

    #[test]
    fn shared_matrix_arg_is_cse_d() {
        // S appears 6 times across both statements but must be a single
        // Arg value in the optimized module.
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(5)).unwrap();
        let m = optimize(teil::from_ast(&prog).unwrap());
        let args = m
            .values
            .iter()
            .filter(|v| matches!(&v.op, Op::Arg { name } if name == "S"))
            .count();
        assert_eq!(args, 1);
    }
}
