//! Affine access-pattern analysis: parallel-read demand per buffer.
//!
//! The hardware-facing question Mnemosyne's banking answers is "how many
//! words of this buffer must be readable in one cycle?". With the
//! innermost reduction loop of every contraction nest fully unrolled
//! (paper §3.4.4, the 11-parallel-multiplier MAC), a buffer read by such
//! a nest is indexed by the unrolled loop variable and must deliver
//! `red_trip` words per cycle. Elementwise and permute nests consume one
//! word per buffer per cycle (stream-order or strided, never unrolled),
//! so their demand is 1. The demand of a buffer is the maximum over the
//! nests that read it — computed here once, globally and per nest range,
//! and consumed by `mnemosyne::plan` instead of ad-hoc re-derivations
//! (the retired `hls::resources::partitions_for`).

use super::affine::{BufId, Kernel, NestKind};

/// Parallel-read demand a single nest places on one of its read buffers.
pub fn nest_read_degree(k: &Kernel, nest: usize, buf: BufId) -> usize {
    let n = &k.nests[nest];
    if !n.reads.contains(&buf) {
        return 0;
    }
    match n.kind {
        // the unrolled reduction reads `red_trip` words of every operand
        // (the streamed tensor slice and the operator matrix column) in
        // the same cycle
        NestKind::Contraction { .. } => n.red_trip,
        NestKind::Elementwise(_) | NestKind::Permute { .. } => 1,
        // one index word and one data row-word per cycle; the *pattern*
        // of the data access is irregular, but the per-cycle word
        // demand is still 1 (the penalty is priced by `hbm::traffic`,
        // not by banking)
        NestKind::Gather { .. } | NestKind::Scatter { .. } => 1,
    }
}

/// Parallel-read demand on `buf` over a range of nests (a dataflow
/// group, or the whole kernel): max over the reading nests, and 1 for a
/// buffer the range never reads (storage still needs one port).
pub fn read_degree_in(k: &Kernel, nests: impl Iterator<Item = usize>, buf: BufId) -> usize {
    nests
        .map(|ni| nest_read_degree(k, ni, buf))
        .max()
        .unwrap_or(0)
        .max(1)
}

/// Per-buffer access summary over the whole kernel.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AccessMap {
    /// Max parallel-read demand per buffer (≥ 1).
    pub read_degree: Vec<usize>,
    /// Nest indices reading each buffer, in nest order.
    pub readers: Vec<Vec<usize>>,
}

/// Analyze every buffer's readers and parallel-read demand.
pub fn analyze(k: &Kernel) -> AccessMap {
    let mut read_degree = vec![1usize; k.buffers.len()];
    let mut readers = vec![Vec::new(); k.buffers.len()];
    for (ni, n) in k.nests.iter().enumerate() {
        for &r in &n.reads {
            readers[r].push(ni);
            read_degree[r] = read_degree[r].max(nest_read_degree(k, ni, r));
        }
    }
    AccessMap { read_degree, readers }
}

/// The kernel's largest parallel-read demand — the partition factor an
/// uncapped memory plan chooses, and the point past which a DSE
/// partition-factor cap is a no-op.
pub fn max_read_degree(k: &Kernel) -> usize {
    k.nests
        .iter()
        .filter(|n| matches!(n.kind, NestKind::Contraction { .. }))
        .map(|n| n.red_trip)
        .max()
        .unwrap_or(1)
        .max(1)
}

/// Does the kernel contain any indirect (gather/scatter) nest? Drives
/// the irregular-access machinery: when false, cache schemes collapse
/// to the bypass default and the traffic model never fires.
pub fn has_indexed(k: &Kernel) -> bool {
    k.nests
        .iter()
        .any(|n| n.kind.index_buffer().is_some())
}

/// Buffers read *through an index* (the gathered data operand of each
/// gather nest) — the candidates for a reuse-aware scratchpad. Index
/// buffers themselves stream in order and are not included. Deduplicated,
/// in first-appearance order.
pub fn indexed_read_buffers(k: &Kernel) -> Vec<BufId> {
    let mut out = Vec::new();
    for n in &k.nests {
        if let NestKind::Gather { .. } = n.kind {
            if let Some(&data) = n.reads.first() {
                if !out.contains(&data) {
                    out.push(data);
                }
            }
        }
    }
    out
}

/// All buffers touched *through an index* in either direction: gather
/// data operands plus scatter targets — the set `mnemosyne` plans a
/// scratchpad for under a caching scheme. Deduplicated, first-appearance
/// order.
pub fn indexed_cache_buffers(k: &Kernel) -> Vec<BufId> {
    let mut out = indexed_read_buffers(k);
    for n in &k.nests {
        if let NestKind::Scatter { .. } = n.kind {
            if !out.contains(&n.write) {
                out.push(n.write);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsl;
    use crate::ir::{lower, rewrite, teil};

    fn helmholtz(p: usize) -> Kernel {
        let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
        let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
        lower::lower_kernel(&m, "helmholtz").unwrap()
    }

    #[test]
    fn contraction_reads_demand_the_reduction_trip() {
        let k = helmholtz(11);
        let am = analyze(&k);
        // every buffer read by a gemm nest needs p parallel words
        for (ni, n) in k.nests.iter().enumerate() {
            if matches!(n.kind, NestKind::Contraction { .. }) {
                for &r in &n.reads {
                    assert!(am.read_degree[r] >= 11, "nest {ni} buf {r}");
                }
            }
        }
        assert_eq!(max_read_degree(&k), 11);
    }

    #[test]
    fn elementwise_only_buffers_demand_one() {
        // `t` (third mode-product output) is consumed only by the
        // hadamard nest — stream-order, one word per cycle.
        let k = helmholtz(11);
        let am = analyze(&k);
        let (tid, _) = k
            .buffers
            .iter()
            .enumerate()
            .find(|(_, b)| b.name == "t")
            .unwrap();
        assert_eq!(am.read_degree[tid], 1);
        assert_eq!(am.readers[tid].len(), 1);
    }

    #[test]
    fn write_only_buffers_default_to_one_port() {
        let k = helmholtz(11);
        let am = analyze(&k);
        for (i, _) in k.outputs() {
            assert_eq!(am.read_degree[i], 1, "outputs are never read back");
            assert!(am.readers[i].is_empty());
        }
    }

    #[test]
    fn range_scoped_degree_sees_only_the_range() {
        let k = helmholtz(11);
        // u is read by nest 0 (gemm, degree p); a range excluding nest 0
        // sees only the default single port
        let (uid, _) = k
            .buffers
            .iter()
            .enumerate()
            .find(|(_, b)| b.name == "u")
            .unwrap();
        assert_eq!(read_degree_in(&k, 0..1, uid), 11);
        assert_eq!(read_degree_in(&k, 1..k.nests.len(), uid), 1);
    }

    #[test]
    fn degrees_agree_with_global_analysis() {
        let k = helmholtz(7);
        let am = analyze(&k);
        for b in 0..k.buffers.len() {
            assert_eq!(am.read_degree[b], read_degree_in(&k, 0..k.nests.len(), b));
        }
    }
}
