//! The PR-10 irregular-access suite: gather/scatter kernels priced by
//! the pseudo-random HBM traffic model and served by the reuse-aware
//! scratchpad schemes.
//!
//!  * the `AccessPattern` model never credits a non-streaming pattern
//!    with more than streaming bandwidth, and captured reuse only ever
//!    helps;
//!  * the analytic bounds still bracket the event simulator on systems
//!    with indexed nests, across cache schemes and CU counts;
//!  * the generic numerics oracle (lowered-kernel interpreter vs
//!    `teil::eval`) agrees exactly on seeded index arrays — duplicates
//!    and out-of-order rows included;
//!  * end-to-end: a gather kernel's simulated makespan degrades vs its
//!    streaming-service equivalent, and a `dse` sweep over the cache
//!    axis yields a frontier where a cached point strictly dominates
//!    the uncached one.

use hbmflow::datatype::DataType;
use hbmflow::dse::{self, Fidelity, SearchSpace};
use hbmflow::flow::{Flow, Mapped, Session};
use hbmflow::hbm::traffic::{schemed_pattern, AccessPattern};
use hbmflow::hls;
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::{BusMode, CacheScheme, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::sim::{self, event::TimelineMode};

const KERNEL_DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/kernels");

/// The two indexed builtins plus the shipped `.cfd` gather program —
/// every front-door surface that lowers to a gather/scatter nest.
fn indexed_library() -> Vec<(String, KernelSource)> {
    vec![
        ("mesh_gather".to_string(), KernelSource::builtin("mesh_gather")),
        (
            "scatter_assembly".to_string(),
            KernelSource::builtin("scatter_assembly"),
        ),
        (
            "gather_interp".to_string(),
            KernelSource::file(format!("{KERNEL_DIR}/gather_interp.cfd")),
        ),
    ]
}

/// Map one indexed kernel under a cache scheme (flat schedule — the
/// memory-bound shape where the traffic model is the binding term).
fn map(src: &KernelSource, scheme: CacheScheme, cus: usize) -> Option<Mapped> {
    Flow::from_source(src.clone())
        .parse(0)
        .and_then(|pa| pa.lower())
        .unwrap_or_else(|e| panic!("{src:?}: {e}"))
        .map(
            &OlympusOpts::baseline().with_cache_scheme(scheme).with_cus(cus),
            &Platform::alveo_u280(),
        )
        .ok()
}

// ---------------------------------------------------------------------
// Property 1: effective random-access bandwidth never exceeds streaming.
// ---------------------------------------------------------------------

#[test]
fn random_access_bandwidth_never_exceeds_streaming() {
    for burst in [1u64, 2, 4, 8, 16, 64, 1024] {
        let streaming = AccessPattern::streaming(burst).efficiency();
        assert_eq!(streaming, 1.0, "streaming is the unit baseline");
        for entropy in [0.0, 0.1, 0.25, 0.5, 0.75, 1.0] {
            for reuse in [1.0, 2.0, 4.0, 16.0, 64.0] {
                let p = AccessPattern { burst_words: burst, stride_entropy: entropy, reuse };
                let eff = p.efficiency();
                assert!(
                    eff > 0.0 && eff <= streaming,
                    "burst {burst} entropy {entropy} reuse {reuse}: {eff}"
                );
                assert!(p.slowdown() >= 1.0);
            }
        }
        // and every schemed view of an indexed stream obeys the same cap
        for scheme in [CacheScheme::Bypass, CacheScheme::Cached(128), CacheScheme::FullBuffer]
        {
            for coverage in [0.0, 0.25, 0.5, 1.0] {
                let eff = schemed_pattern(burst, 4.0, scheme, coverage).efficiency();
                assert!(eff > 0.0 && eff <= 1.0, "{scheme:?} cov {coverage}: {eff}");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Property 2: captured reuse is monotone — more reuse, more bandwidth.
// ---------------------------------------------------------------------

#[test]
fn efficiency_is_monotone_in_reuse_and_cache_coverage() {
    for burst in [1u64, 8, 16, 64] {
        let mut last = 0.0;
        for reuse in 1..=64 {
            let eff = AccessPattern::random(burst, reuse as f64).efficiency();
            assert!(eff >= last, "burst {burst} reuse {reuse}: {eff} < {last}");
            last = eff;
        }
    }
    // a capacity-bounded scratchpad improves with coverage (same intrinsic
    // reuse, larger captured fraction) and with intrinsic reuse at fixed
    // coverage — the degree-of-reuse knob only ever helps
    for reuse in [2.0, 4.0, 16.0] {
        let mut last = 0.0;
        for cov in [0.0, 0.125, 0.25, 0.5, 0.75, 1.0] {
            let eff = schemed_pattern(8, reuse, CacheScheme::Cached(64), cov).efficiency();
            assert!(eff >= last, "reuse {reuse} cov {cov}: {eff} < {last}");
            last = eff;
        }
    }
    let mut last = 0.0;
    for reuse in 1..=32 {
        let eff =
            schemed_pattern(8, reuse as f64, CacheScheme::Cached(64), 0.5).efficiency();
        assert!(eff >= last, "reuse {reuse}: {eff} < {last}");
        last = eff;
    }
}

// ---------------------------------------------------------------------
// Property 3: analytic bounds still bracket the event simulator on
// gather/scatter systems, across cache schemes and CU counts.
// ---------------------------------------------------------------------

#[test]
fn analytic_bounds_bracket_event_sim_for_indexed_kernels() {
    let platform = Platform::alveo_u280();
    let mut points = 0usize;
    for (label, src) in indexed_library() {
        for scheme in [CacheScheme::Bypass, CacheScheme::Cached(128), CacheScheme::FullBuffer]
        {
            for cus in [1usize, 4] {
                let Some(m) = map(&src, scheme, cus) else { continue };
                let est = hls::estimate(&m.spec, &platform);
                for n in [120_000u64, 2_000_000] {
                    let ev = sim::simulate_with_timeline(
                        &m.spec,
                        &est,
                        &platform,
                        n,
                        TimelineMode::Sequential,
                    );
                    let an = sim::analytic::simulate_analytic(&m.spec, &est, &platform, n);
                    let b = an.analytic.expect("analytic result carries its bracket");
                    let ctx = format!("{label} × {scheme:?} × {cus}cu × {n}");
                    assert!(
                        b.brackets(ev.total_time_s),
                        "{ctx}: bracket {b:?} misses event makespan {}",
                        ev.total_time_s
                    );
                    // the conservative orientation dse pruning depends on
                    assert_eq!(an.total_time_s.to_bits(), b.upper_s.to_bits(), "{ctx}");
                    assert_eq!(an.batches, ev.batches, "{ctx}: batches");
                    assert_eq!(an.total_flops, ev.total_flops, "{ctx}: flops");
                    points += 1;
                }
            }
        }
    }
    assert!(points >= 12, "only {points} indexed grid points were mappable");
}

// ---------------------------------------------------------------------
// Property 4: the generic numerics oracle covers indexed kernels — the
// lowered-kernel interpreter and teil::eval agree exactly on seeded
// index arrays (duplicates and out-of-order rows included).
// ---------------------------------------------------------------------

#[test]
fn interp_and_teil_agree_on_seeded_index_arrays() {
    for (label, src) in indexed_library() {
        // the workload generator draws index entries uniformly from
        // [0, rows): 1024 draws over 256 rows force duplicates, and
        // uniform order is arbitrary — exactly the hostile case
        let Some(m) = map(&src, CacheScheme::Bypass, 1) else {
            panic!("{label}: baseline system must map");
        };
        for seed in [2024u64, 0xC0FFEE] {
            let check = m.oracle(seed, 3).unwrap_or_else(|e| panic!("{label}: {e}"));
            assert_eq!(check.elements, 3, "{label}");
            assert_eq!(check.mse, 0.0, "{label} seed {seed}: MSE {}", check.mse);
            assert_eq!(
                check.max_abs_err, 0.0,
                "{label} seed {seed}: max|err| {}",
                check.max_abs_err
            );
        }
    }
}

// ---------------------------------------------------------------------
// Acceptance: the gather kernel's simulated makespan is degraded vs the
// streaming-service equivalent, and scratchpads claw the gap back in
// scheme order.
// ---------------------------------------------------------------------

#[test]
fn gather_bandwidth_degrades_vs_streaming_and_caches_recover_it() {
    let platform = Platform::alveo_u280();
    let src = KernelSource::builtin("mesh_gather");
    let n = 1_000_000u64;
    let time = |scheme: CacheScheme| {
        let m = map(&src, scheme, 1).expect("baseline mesh_gather maps");
        let est = hls::estimate(&m.spec, &platform);
        sim::simulate_with_timeline(&m.spec, &est, &platform, n, TimelineMode::Sequential)
            .total_time_s
    };
    let bypass = time(CacheScheme::Bypass);
    let cached = time(CacheScheme::Cached(128));
    let full = time(CacheScheme::FullBuffer);
    // FullBuffer serves the gather from an on-chip copy, so HBM sees the
    // streaming pass a dense kernel would issue: it is the streaming
    // equivalent. The uncached gather must be strictly slower (the
    // whole point of the pseudo-random traffic model), a partial
    // scratchpad strictly in between (it captures some of the reuse).
    assert!(
        bypass > full,
        "random access must cost bandwidth: bypass {bypass} vs streaming {full}"
    );
    assert!(
        bypass > 1.05 * full,
        "the degradation should be material, not roundoff: {bypass} vs {full}"
    );
    assert!(
        full < cached && cached < bypass,
        "schemes must order the makespan: {full} < {cached} < {bypass}"
    );
}

// ---------------------------------------------------------------------
// Acceptance: a dse sweep over the cache axis produces a frontier where
// a cached point strictly dominates the uncached one.
// ---------------------------------------------------------------------

#[test]
fn dse_cache_sweep_cached_point_dominates_bypass() {
    // one-axis sweep: everything pinned to the flat baseline shape, only
    // the cache scheme varies. Cached(128) = 1024 data bytes stays in
    // LUTRAM, so it beats Bypass on time (hence GFLOPS and energy) at
    // identical BRAM/URAM/DSP — strict dominance. FullBuffer trades a
    // URAM bank for full streaming service, so it survives alongside.
    let mut space = SearchSpace::for_source(KernelSource::builtin("mesh_gather"));
    space.dtypes = vec![DataType::F64];
    space.cu_counts = vec![1];
    space.dataflow = vec![None];
    space.double_buffering = vec![false];
    space.bus_modes = vec![BusMode::Narrow64];
    space.mem_sharing = vec![false];
    space.fifo_depths = vec![None];
    space.cache_schemes = vec![
        CacheScheme::Bypass,
        CacheScheme::Cached(128),
        CacheScheme::FullBuffer,
    ];
    let session = Session::new(Platform::alveo_u280());
    let ex = dse::explore_in_with(&session, &space, 1_000_000, Some(1), Fidelity::Exact)
        .expect("sweep runs");
    assert_eq!(ex.outcomes.len(), 3, "one point per scheme");

    let idx = |scheme: CacheScheme| {
        ex.outcomes
            .iter()
            .position(|o| o.point.opts.cache_scheme == scheme)
            .unwrap_or_else(|| panic!("{scheme:?} missing from sweep"))
    };
    let objectives = |i: usize| {
        let o = &ex.outcomes[i];
        assert!(o.is_feasible(), "{}: {:?}", o.point.label(), o.result);
        dse::pareto::objectives(o.result.as_ref().unwrap())
    };
    let bypass = idx(CacheScheme::Bypass);
    let cached = idx(CacheScheme::Cached(128));
    let full = idx(CacheScheme::FullBuffer);

    assert!(
        dse::dominates(&objectives(cached), &objectives(bypass)),
        "cached {:?} must dominate bypass {:?}",
        objectives(cached),
        objectives(bypass)
    );
    assert!(
        !ex.is_on_frontier(bypass),
        "the uncached point cannot survive a dominating cached one"
    );
    assert!(ex.is_on_frontier(cached), "the dominating point is on the frontier");
    // FullBuffer is a genuine trade (fastest, but it buys a URAM bank):
    // the frontier keeps it rather than collapsing to a single winner
    assert!(ex.is_on_frontier(full), "streaming-service point survives as a trade");
}

// ---------------------------------------------------------------------
// Acceptance: the gather kernel runs end-to-end through the CLI front
// door with the oracle check in the output.
// ---------------------------------------------------------------------

#[test]
fn cli_simulates_the_gather_example_with_a_clean_oracle() {
    let file = format!("{KERNEL_DIR}/gather_interp.cfd");
    for scheme in ["bypass", "cached:64", "full"] {
        let argv: Vec<String> = [
            "simulate",
            "--file",
            &file,
            "--preset",
            "baseline",
            "--cache-scheme",
            scheme,
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let out = hbmflow::cli::main_with_args(&argv)
            .unwrap_or_else(|e| panic!("--cache-scheme {scheme}: {e}"));
        assert!(out.contains("oracle"), "--cache-scheme {scheme}: {out}");
        assert!(
            out.contains("MSE 0.000e0"),
            "--cache-scheme {scheme}: oracle must be exact: {out}"
        );
    }
}
