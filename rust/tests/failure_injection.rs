//! Integration: failure injection — the system must fail loudly and
//! precisely, never silently.

use std::fs;

use hbmflow::cli::build_kernel;
use hbmflow::dsl;
use hbmflow::ir::{lower, rewrite, schedule, teil};
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::runtime::{manifest::Manifest, Runtime};

fn tmpdir(name: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("hbmflow_fi_{name}"));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

#[test]
fn missing_artifacts_dir_reports_make_hint() {
    let err = match Runtime::new("/nonexistent/path") {
        Err(e) => e,
        Ok(_) => panic!("must fail"),
    };
    assert!(err.to_string().contains("make artifacts"), "{err}");
}

#[test]
fn corrupt_hlo_text_fails_at_load_not_execute() {
    let dir = tmpdir("corrupt_hlo");
    fs::write(
        dir.join("manifest.json"),
        r#"{"format":"hlo-text","artifacts":[{"name":"bad","path":"bad.hlo.txt",
            "kernel":"helmholtz","p":7,"dtype":"f64","batch":8,"variant":"pallas",
            "flops_per_element":29155,"num_outputs":1,
            "inputs":[{"shape":[7,7],"dtype":"float64"}]}]}"#
            .replace('\n', " "),
    )
    .unwrap();
    fs::write(dir.join("bad.hlo.txt"), "HloModule nonsense ENTRY {").unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    let err = rt.run_f64("bad", &[vec![0.0; 49]]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("parse") || msg.contains("bad.hlo.txt") || msg.contains("compile"),
        "{msg}"
    );
}

#[test]
fn manifest_shape_mismatch_is_caught_before_pjrt() {
    let Ok(mut rt) = Runtime::from_default_dir() else {
        eprintln!("artifacts missing; skipping");
        return;
    };
    // deliberately wrong input length
    let err = rt
        .run_f64(
            "helmholtz_p7_f64_b8",
            &[vec![0.0; 10], vec![0.0; 10], vec![0.0; 10]],
        )
        .unwrap_err();
    assert!(err.to_string().contains("input size"), "{err}");
}

#[test]
fn manifest_missing_fields_rejected() {
    let dir = tmpdir("missing_fields");
    fs::write(
        dir.join("manifest.json"),
        r#"{"format":"hlo-text","artifacts":[{"name":"x"}]}"#,
    )
    .unwrap();
    let err = Manifest::load(&dir).unwrap_err();
    assert!(err.contains("missing"), "{err}");
}

#[test]
fn dsl_semantic_errors_surface_with_context() {
    for (src, needle) in [
        ("var output v : [2]\nv = w", "undeclared"),
        ("var input a : [2 2]\nvar output v : [2]\nv = a . [[0 3]]", "out of range"),
        ("var input a : [2]\nvar output v : [4]\nv = a", "shape mismatch"),
    ] {
        let err = dsl::parse(src)
            .map_err(|e| e)
            .and_then(|p| teil::from_ast(&p).map(|_| ()))
            .unwrap_err();
        assert!(err.contains(needle), "{src}: {err}");
    }
}

#[test]
fn olympus_rejects_impossible_configurations() {
    let k = build_kernel("helmholtz", 11).unwrap();
    let platform = Platform::alveo_u280();
    // 0 CUs
    let mut o = OlympusOpts::baseline();
    o.num_cus = 0;
    assert!(olympus::generate(&k, &o, &platform).is_err());
    // 17 double-buffered CUs exceed the PC budget
    let mut o = OlympusOpts::double_buffering();
    o.num_cus = 17;
    assert!(olympus::generate(&k, &o, &platform).is_err());
    // dataflow with more groups than nests
    let mut o = OlympusOpts::baseline();
    o.dataflow = Some(99);
    assert!(olympus::generate(&k, &o, &platform).is_err());
}

#[test]
fn schedule_and_kernel_validation_catch_corruption() {
    let prog = dsl::parse(&dsl::inverse_helmholtz_source(7)).unwrap();
    let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
    let mut k = lower::lower_kernel(&m, "helmholtz").unwrap();
    let s = schedule::fixed(&k, 3).unwrap();
    // corrupt the kernel after scheduling: validation must catch it
    k.nests[0].out_trips = vec![1];
    assert!(k.validate().is_err());
    // and a schedule over a different nest count must not validate
    let k2 = build_kernel("interpolation", 11).unwrap();
    assert!(s.validate(&k2).is_err());
}

#[test]
fn element_too_large_for_channel_is_rejected() {
    // a degree so large one element exceeds 256 MB
    let src = dsl::inverse_helmholtz_source(260); // 260^3 * 2 * 8B > 256MB
    let prog = dsl::parse(&src).unwrap();
    let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
    let k = lower::lower_kernel(&m, "huge").unwrap();
    let err = olympus::generate(&k, &OlympusOpts::baseline(), &Platform::alveo_u280())
        .unwrap_err();
    assert!(err.contains("too large"), "{err}");
}
