//! Integration: every shipped `examples/kernels/*.cfd` program flows
//! through the whole stack — parse, lossless rewrite (naive vs
//! optimized `teil::eval`), lower, Olympus generation under the
//! baseline preset, a small simulation run, and the generic numerics
//! oracle — plus a dse smoke test over a file-sourced kernel. A grammar
//! or lowering regression on user-facing programs fails here.

use std::path::PathBuf;

use hbmflow::coordinator::GenericWorkload;
use hbmflow::datatype::DataType;
use hbmflow::dse::{self, SearchSpace};
use hbmflow::ir::teil;
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::{self, BusMode, OlympusOpts};
use hbmflow::platform::Platform;

fn kernel_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/kernels")
}

fn shipped_kernels() -> Vec<PathBuf> {
    let mut files: Vec<PathBuf> = std::fs::read_dir(kernel_dir())
        .expect("examples/kernels exists")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "cfd"))
        .collect();
    files.sort();
    files
}

#[test]
fn the_library_ships_at_least_five_kernels() {
    assert!(
        shipped_kernels().len() >= 5,
        "kernel library shrank: {:?}",
        shipped_kernels()
    );
}

#[test]
fn every_shipped_kernel_compiles_rewrites_losslessly_and_simulates() {
    let platform = Platform::alveo_u280();
    for path in shipped_kernels() {
        let name = path.file_stem().unwrap().to_string_lossy().into_owned();
        let source = KernelSource::file(&path);

        // parse + both IR forms (module and kernel from one parse)
        let naive = source.module_naive(0).unwrap_or_else(|e| panic!("{e}"));
        let (opt, k) = source.compile(0).unwrap_or_else(|e| panic!("{e}"));
        assert!(!opt.defs.is_empty(), "{name}");

        // lossless rewrite: naive and optimized teil::eval agree on
        // seeded inputs (kernel extents are chosen so the naive
        // outer-product materialization stays affordable)
        let w = GenericWorkload::new(&name, opt.clone(), k.clone(), 77);
        let inputs = w.element_inputs(0);
        let a = teil::eval(&naive, &inputs).unwrap();
        let b = teil::eval(&opt, &inputs).unwrap();
        for d in opt.outputs() {
            let diff = a[&d.name].max_abs_diff(&b[&d.name]);
            assert!(diff < 1e-10, "{name}/{}: rewrite drift {diff}", d.name);
        }

        // the generic oracle: lowered kernel vs teil::eval, exact
        let check = w.check(2).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(check.mse, 0.0, "{name}: oracle MSE {:.3e}", check.mse);

        // hardware generation + simulation at a small size
        k.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
        let spec = olympus::generate(&k, &OlympusOpts::baseline(), &platform)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        spec.validate(&platform).unwrap_or_else(|e| panic!("{name}: {e}"));
        let est = hbmflow::hls::estimate(&spec, &platform);
        assert!(est.fmax_mhz > 50.0, "{name}");
        let r = hbmflow::sim::simulate(&spec, &est, &platform, 20_000);
        assert!(r.gflops_system > 0.0, "{name}");
    }
}

#[test]
fn every_shipped_kernel_compiles_through_the_cli_in_all_emit_modes() {
    for path in shipped_kernels() {
        let f = path.to_str().unwrap();
        for emit in ["c", "cfg", "wrapper", "host", "teil"] {
            let args: Vec<String> =
                ["compile", "--file", f, "--emit", emit]
                    .iter()
                    .map(|s| s.to_string())
                    .collect();
            let out = hbmflow::cli::main_with_args(&args)
                .unwrap_or_else(|e| panic!("{f} --emit {emit}: {e}"));
            assert!(!out.is_empty(), "{f} --emit {emit}");
        }
    }
}

#[test]
fn file_sourced_dse_produces_a_nonempty_frontier() {
    let path = kernel_dir().join("advect.cfd");
    let mut s = SearchSpace::for_source(KernelSource::file(&path));
    // narrow slice so the debug-mode test stays fast
    s.dtypes = vec![DataType::F64];
    s.cu_counts = vec![1];
    s.dataflow = vec![Some(3)];
    s.double_buffering = vec![true];
    s.bus_modes = vec![BusMode::Wide256Parallel];
    s.mem_sharing = vec![false];
    s.fifo_depths = vec![None];
    let ex = dse::explore(&s, &Platform::alveo_u280(), 50_000, Some(2)).unwrap();
    assert_eq!(ex.kernel, "advect");
    assert!(ex.feasible_count() > 0);
    assert!(!ex.frontier.is_empty());
    let report = dse::report::text(&ex, 0, true);
    assert!(report.contains("kernel: advect"), "{report}");
    assert!(report.contains("Pareto frontier"), "{report}");
}

#[test]
fn file_sourced_simulate_reports_gflops_and_oracle_mse() {
    for file in ["stiffness.cfd", "smoother.cfd"] {
        let path = kernel_dir().join(file);
        let args: Vec<String> = [
            "sim",
            "--file",
            path.to_str().unwrap(),
            "--preset",
            "baseline",
            "--elements",
            "20000",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let out = hbmflow::cli::main_with_args(&args).unwrap();
        assert!(out.contains("GFLOPS"), "{file}: {out}");
        assert!(out.contains("oracle"), "{file}: {out}");
        assert!(out.contains("MSE 0.000e0"), "{file}: {out}");
    }
}
