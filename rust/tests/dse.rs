//! Integration: the design-space exploration engine end to end
//! (DESIGN.md §5, experiment E10) — the space is large, the frontier is
//! sound, and the paper's published design points survive on it.

use hbmflow::datatype::DataType;
use hbmflow::dse::{self, pareto, SearchSpace};
use hbmflow::olympus::BusMode;
use hbmflow::platform::Platform;
use hbmflow::report::paper;

/// The Fig. 16 slice of the space: Dataflow-7 across dtype / degree /
/// CU count, exactly the grid the paper's §4.2 evaluation walks.
fn fig16_slice() -> SearchSpace {
    let mut s = SearchSpace::default_for("helmholtz");
    s.dataflow = vec![Some(7)];
    s.double_buffering = vec![true];
    s.bus_modes = vec![BusMode::Wide256Parallel];
    s.mem_sharing = vec![false];
    s.fifo_depths = vec![None];
    s
}

#[test]
fn default_space_enumerates_at_least_100_candidates() {
    let n = SearchSpace::default_for("helmholtz").enumerate().len();
    assert!(n >= 100, "default space has only {n} candidates");
}

#[test]
fn fig16_best_fixed_point_config_is_frontier_feasible() {
    let ex = dse::explore(
        &fig16_slice(),
        &Platform::alveo_u280(),
        paper::N_ELEMENTS,
        Some(2),
    )
    .unwrap();

    let i = ex
        .find_config(DataType::Fx32, 11, Some(7), 1)
        .expect("the paper's Fig. 16 custom-precision config is enumerated");
    let e = ex.outcomes[i].result.as_ref().expect("generates cleanly");
    assert!(e.feasible, "fx32 p=11 DF7 1CU must fit the U280");
    assert!(
        ex.is_on_frontier(i),
        "the paper's chosen custom-precision point must be Pareto-optimal"
    );
    // and it lands in the paper's ~103 GFLOPS neighborhood (Fig. 16)
    assert!(
        (70.0..140.0).contains(&e.sim.gflops_system),
        "fx32 p=11: {} GFLOPS",
        e.sim.gflops_system
    );
}

#[test]
fn frontier_contains_no_dominated_and_no_infeasible_point() {
    let ex = dse::explore(
        &fig16_slice(),
        &Platform::alveo_u280(),
        paper::N_ELEMENTS,
        Some(2),
    )
    .unwrap();
    assert!(!ex.frontier.is_empty());

    let obj =
        |i: usize| pareto::objectives(ex.outcomes[i].result.as_ref().unwrap());
    for &i in &ex.frontier {
        assert!(ex.outcomes[i].is_feasible(), "{}", ex.outcomes[i].point.label());
        // nothing feasible anywhere in the space dominates a frontier member
        for (j, o) in ex.outcomes.iter().enumerate() {
            if j != i && o.is_feasible() {
                assert!(
                    !pareto::dominates(&obj(j), &obj(i)),
                    "{} dominates frontier member {}",
                    o.point.label(),
                    ex.outcomes[i].point.label()
                );
            }
        }
    }
    // and every feasible non-member is dominated by someone
    for (j, o) in ex.outcomes.iter().enumerate() {
        if o.is_feasible() && !ex.is_on_frontier(j) {
            assert!(
                ex.outcomes
                    .iter()
                    .enumerate()
                    .any(|(k, q)| k != j
                        && q.is_feasible()
                        && pareto::dominates(&obj(k), &obj(j))),
                "{} is off-frontier yet undominated",
                o.point.label()
            );
        }
    }
}

#[test]
fn paper_fig15_df7_double_is_on_or_near_the_frontier() {
    // Degree is a *problem* parameter as much as a design axis: a p=7
    // design can undercut a p=11 one on every objective while solving a
    // smaller discretization. The Fig. 15 endpoint's frontier claim is
    // therefore made within its own degree, p = 11 — exactly the slice
    // Fig. 15 itself plots.
    let mut space = fig16_slice();
    space.degrees = vec![11];
    let ex = dse::explore(
        &space,
        &Platform::alveo_u280(),
        paper::N_ELEMENTS,
        Some(2),
    )
    .unwrap();
    let i = ex.find_config(DataType::F64, 11, Some(7), 1).unwrap();
    let e = ex.outcomes[i].result.as_ref().unwrap();
    assert!(e.feasible);
    // Fig. 15's endpoint reproduces (~43 GFLOPS neighborhood) and is
    // Pareto-optimal at p=11: fixed point beats it on throughput but
    // pays DSP (fx64) or BRAM (fx32/f32), so double precision survives.
    assert!((30.0..60.0).contains(&e.sim.gflops_system));
    assert!(ex.is_on_frontier(i), "f64 p=11 DF7 should survive the frontier");
}

#[test]
fn multi_cu_replication_is_dominated_as_the_paper_concludes() {
    // Paper Fig. 17: replication scales CU throughput but the system
    // slows down (PCIe serialization) while resources triple — so the
    // 3-CU point must NOT be on the frontier when 1-CU variants exist.
    let ex = dse::explore(
        &fig16_slice(),
        &Platform::alveo_u280(),
        paper::N_ELEMENTS,
        Some(2),
    )
    .unwrap();
    if let Some(i) = ex.find_config(DataType::Fx32, 11, Some(7), 3) {
        if ex.outcomes[i].is_feasible() {
            assert!(
                !ex.is_on_frontier(i),
                "3-CU replication should be dominated (paper: \"it is not \
                 recommended to replicate CUs\")"
            );
        }
    }
}
