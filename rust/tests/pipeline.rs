//! Integration: the full DSL -> system pipeline across all three kernels.

use hbmflow::cli::build_kernel;
use hbmflow::codegen::c_emit;
use hbmflow::datatype::DataType;
use hbmflow::dsl;
use hbmflow::hls;
use hbmflow::ir::{liveness, lower, rewrite, schedule, teil};
use hbmflow::mnemosyne;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;

#[test]
fn helmholtz_full_pipeline_golden() {
    let src = dsl::inverse_helmholtz_source(11);
    let prog = dsl::parse(&src).unwrap();
    let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
    let k = lower::lower_kernel(&m, "helmholtz").unwrap();
    assert_eq!(k.nests.len(), 7);
    assert_eq!(k.flops_per_element(), 177_023);

    let s = schedule::fixed(&k, 3).unwrap();
    let c = c_emit::emit(&k, &s, "f64");
    // golden fragments (Fig. 12b style)
    assert!(c.contains("void gemm_0("));
    assert!(c.contains("void mmult_1("));
    assert!(c.contains("void gemm_inv_2("));
    assert!(c.contains("121 * c0 + 11 * c1 + c2"));
    assert!(c.contains("#pragma HLS unroll"));

    let lv = liveness::analyze(&k);
    let plan = mnemosyne::share(&k, &lv, None);
    plan.validate(&k, &lv).unwrap();

    let platform = Platform::alveo_u280();
    let spec = olympus::generate(&k, &OlympusOpts::dataflow(7), &platform).unwrap();
    spec.validate(&platform).unwrap();
    let cfg = olympus::config::system_cfg(&spec);
    assert!(cfg.contains("sp=helmholtz_1.m_axi_read0:HBM[0]"));

    let est = hls::estimate(&spec, &platform);
    assert_eq!(est.ops(), 532);
}

#[test]
fn all_kernels_compile_through_every_stage() {
    let platform = Platform::alveo_u280();
    for (name, p, groups) in [
        ("helmholtz", 7, 7),
        ("helmholtz", 11, 2),
        ("interpolation", 11, 3),
        ("gradient", 8, 3),
    ] {
        let k = build_kernel(name, p).unwrap();
        k.validate().unwrap();
        let s = schedule::fixed(&k, groups.min(k.nests.len())).unwrap();
        s.validate(&k).unwrap();
        let c = c_emit::emit(&k, &s, "f64");
        assert!(c.contains("void "), "{name}");
        let mut opts = OlympusOpts::dataflow(groups.min(k.nests.len()));
        opts.dtype = DataType::F64;
        let spec = olympus::generate(&k, &opts, &platform).unwrap();
        spec.validate(&platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        assert!(est.fmax_mhz > 60.0, "{name}");
        let r = hbmflow::sim::simulate(&spec, &est, &platform, 100_000);
        assert!(r.gflops_system > 0.0, "{name}");
    }
}

#[test]
fn fixed_point_pipeline_emits_ap_fixed_everywhere() {
    let k = build_kernel("helmholtz", 11).unwrap();
    let s = schedule::fixed(&k, 7).unwrap();
    for (dt, pat) in [("fx64", "ap_fixed<64, 24>"), ("fx32", "ap_fixed<32, 8>")] {
        let c = c_emit::emit(&k, &s, dt);
        assert!(c.contains(pat), "{dt}");
    }
    let platform = Platform::alveo_u280();
    let spec = olympus::generate(
        &k,
        &OlympusOpts::fixed_point(DataType::Fx32),
        &platform,
    )
    .unwrap();
    // host program must include the double<->fixed conversions
    let hp = olympus::config::host_program(&spec);
    assert!(hp.contains("ConvertToDevice"));
    assert!(hp.contains("ConvertFromDevice"));
    assert_eq!(spec.lanes, 8);
}

#[test]
fn interpolation_pipeline_flops_model() {
    let k = build_kernel("interpolation", 11).unwrap();
    // 3 mode products, 2 * 11 per output element each
    assert_eq!(k.flops_per_element(), 3 * 2 * 11 * 1331);
    assert_eq!(k.input_words(), 121 + 1331);
    assert_eq!(k.output_words(), 1331);
}

#[test]
fn gradient_pipeline_structure() {
    let k = build_kernel("gradient", 8).unwrap();
    // 3 contractions + 2 permutes (gy, gz axis restore)
    assert_eq!(k.nests.len(), 5);
    assert_eq!(k.outputs().count(), 3);
    let s = schedule::auto(&k, None);
    s.validate(&k).unwrap();
}

#[test]
fn cli_surface_smoke() {
    let run = |args: &[&str]| {
        let v: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        hbmflow::cli::main_with_args(&v).unwrap()
    };
    assert!(run(&["compile", "--kernel", "interpolation", "--emit", "c"]).contains("void"));
    assert!(run(&["estimate", "--preset", "mem-sharing"]).contains("ops:"));
    assert!(
        run(&["simulate", "--preset", "dataflow7", "--dtype", "fx32", "--elements", "500000"])
            .contains("GFLOPS/W")
    );
    assert!(run(&["sweep", "--elements", "200000"]).contains("configuration"));
}
