//! The PR-6 differential test layer: the three simulator paths —
//! sequential event timeline, parallel event timeline, closed-form
//! analytic bounds — checked against each other across the whole
//! kernel library.
//!
//!  * analytic vs event: the bracket `lower <= makespan <= upper` and
//!    the gap contract `rel_gap <= (cus + 1) / n_batches` hold at every
//!    grid point (all six `examples/kernels/*.cfd` plus the three
//!    builtins × CU counts × seeded element counts), and every
//!    timeline-independent `SimResult` field agrees exactly;
//!  * parallel vs sequential: the full `SimResult` is bit-identical,
//!    field for field;
//!  * regression pins: the Fig. 17 multi-CU shape and the Table 3
//!    Mem-Sharing deltas are unchanged by the parallel timeline.

use hbmflow::cli::build_kernel;
use hbmflow::datatype::DataType;
use hbmflow::flow::{Flow, Mapped};
use hbmflow::hls;
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::report::paper;
use hbmflow::sim::{self, event::TimelineMode, SimResult};
use hbmflow::util::prng::Prng;

const KERNEL_DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/kernels");

/// The full front-door surface: three builtins (gradient has fixed
/// extents; its degree argument is nominal) plus every shipped `.cfd`
/// kernel (fixed extents, degree 0).
fn library() -> Vec<(String, KernelSource, usize)> {
    let mut v = vec![
        ("helmholtz".to_string(), KernelSource::builtin("helmholtz"), 11),
        (
            "interpolation".to_string(),
            KernelSource::builtin("interpolation"),
            11,
        ),
        ("gradient".to_string(), KernelSource::builtin("gradient"), 8),
    ];
    for f in [
        "advect",
        "fused_helmholtz_grad",
        "interp2d",
        "mass_apply",
        "smoother",
        "stiffness",
    ] {
        v.push((
            f.to_string(),
            KernelSource::file(format!("{KERNEL_DIR}/{f}.cfd")),
            0,
        ));
    }
    v
}

/// Map one library entry at a CU count (dataflow groups clamped to the
/// kernel's nest count). `None` when the platform's channel budget
/// cannot host the corner — the grid records what is mappable.
fn map(src: &KernelSource, p: usize, cus: usize) -> Option<Mapped> {
    let lowered = Flow::from_source(src.clone())
        .parse(p)
        .and_then(|pa| pa.lower())
        .unwrap_or_else(|e| panic!("{src:?}: {e}"));
    let groups = lowered.kernel.nests.len().clamp(1, 7);
    lowered
        .map(&OlympusOpts::dataflow(groups).with_cus(cus), &Platform::alveo_u280())
        .ok()
}

fn rel_close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1e-300)
}

/// Field-for-field bit identity (f64 via `to_bits`); the exhaustive
/// form of the satellite "parallel timeline is bit-identical" claim.
fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    let f = |x: f64, y: f64, name: &str| {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name} differs ({x} vs {y})");
    };
    assert_eq!(a.label, b.label, "{ctx}");
    f(a.total_time_s, b.total_time_s, "total_time_s");
    f(a.cu_time_s, b.cu_time_s, "cu_time_s");
    f(a.transfer_time_s, b.transfer_time_s, "transfer_time_s");
    f(a.gflops_system, b.gflops_system, "gflops_system");
    f(a.gflops_cu, b.gflops_cu, "gflops_cu");
    f(a.freq_mhz, b.freq_mhz, "freq_mhz");
    f(a.ideal_gflops, b.ideal_gflops, "ideal_gflops");
    f(a.efficiency_vs_ideal, b.efficiency_vs_ideal, "efficiency_vs_ideal");
    f(a.avg_power_w, b.avg_power_w, "avg_power_w");
    f(a.efficiency_gflops_w, b.efficiency_gflops_w, "efficiency_gflops_w");
    f(a.energy_j, b.energy_j, "energy_j");
    f(
        a.max_channel_utilization,
        b.max_channel_utilization,
        "max_channel_utilization",
    );
    assert_eq!(a.batches, b.batches, "{ctx}: batches");
    assert_eq!(a.batch_elements, b.batch_elements, "{ctx}: batch_elements");
    assert_eq!(a.stage_intervals, b.stage_intervals, "{ctx}: stage_intervals");
    assert_eq!(a.bottleneck, b.bottleneck, "{ctx}: bottleneck");
    assert_eq!(a.total_flops, b.total_flops, "{ctx}: total_flops");
    assert_eq!(
        a.channel_utilization.len(),
        b.channel_utilization.len(),
        "{ctx}: channel_utilization length"
    );
    for ((ca, ua), (cb, ub)) in a.channel_utilization.iter().zip(&b.channel_utilization) {
        assert_eq!(ca, cb, "{ctx}: channel order");
        f(*ua, *ub, "channel utilization");
    }
    assert_eq!(a.switch_crossings, b.switch_crossings, "{ctx}: switch_crossings");
    assert_eq!(a.hbm_fill_cycles, b.hbm_fill_cycles, "{ctx}: hbm_fill_cycles");
    assert_eq!(a.conflict_stalls, b.conflict_stalls, "{ctx}: conflict_stalls");
    assert_eq!(a.mem_banks, b.mem_banks, "{ctx}: mem_banks");
    assert_eq!(a.mem_shared_words, b.mem_shared_words, "{ctx}: mem_shared_words");
    assert_eq!(
        a.mem_unshared_words, b.mem_unshared_words,
        "{ctx}: mem_unshared_words"
    );
    assert_eq!(a.analytic, b.analytic, "{ctx}: analytic");
}

// ---------------------------------------------------------------------
// Satellite 1: analytic vs event differential over the kernel library.
// ---------------------------------------------------------------------

#[test]
fn analytic_bounds_bracket_event_sim_across_the_kernel_library() {
    let platform = Platform::alveo_u280();
    let mut rng = Prng::new(0x5EED_0006);
    let mut max_gap_12 = 0.0f64; // over points with >= 12 batches
    let mut max_gap_100 = 0.0f64; // over points with >= 100 batches
    let mut points = 0usize;

    for (label, src, p) in library() {
        for cus in [1usize, 4, 8] {
            let Some(m) = map(&src, p, cus) else { continue };
            let est = hls::estimate(&m.spec, &platform);
            // one pinned workload plus two seeded draws per system
            let elems = [
                2_000_000u64,
                rng.range_u64(1_000_000, 6_000_000),
                rng.range_u64(1_000_000, 6_000_000),
            ];
            for n in elems {
                let ev = sim::simulate_with_timeline(
                    &m.spec,
                    &est,
                    &platform,
                    n,
                    TimelineMode::Sequential,
                );
                let an = sim::analytic::simulate_analytic(&m.spec, &est, &platform, n);
                let b = an.analytic.expect("analytic result must carry its bracket");
                let ctx = format!("{label} × {cus}cu × {n}");

                // the bracket and its advertised tightness
                assert!(
                    b.brackets(ev.total_time_s),
                    "{ctx}: bracket {b:?} misses event makespan {}",
                    ev.total_time_s
                );
                let contract = (cus as f64 + 1.0) / ev.batches.max(1) as f64 + 1e-6;
                assert!(
                    b.rel_gap() <= contract,
                    "{ctx}: rel_gap {} exceeds contract {contract}",
                    b.rel_gap()
                );
                // the conservative orientation dse pruning depends on
                assert_eq!(an.total_time_s.to_bits(), b.upper_s.to_bits(), "{ctx}");

                // every timeline-independent field agrees exactly...
                assert_eq!(an.batches, ev.batches, "{ctx}: batches");
                assert_eq!(an.batch_elements, ev.batch_elements, "{ctx}");
                assert_eq!(an.stage_intervals, ev.stage_intervals, "{ctx}");
                assert_eq!(an.conflict_stalls, ev.conflict_stalls, "{ctx}");
                assert_eq!(an.switch_crossings, ev.switch_crossings, "{ctx}");
                assert_eq!(an.hbm_fill_cycles, ev.hbm_fill_cycles, "{ctx}");
                assert_eq!(an.mem_banks, ev.mem_banks, "{ctx}");
                assert_eq!(an.mem_shared_words, ev.mem_shared_words, "{ctx}");
                assert_eq!(an.freq_mhz.to_bits(), ev.freq_mhz.to_bits(), "{ctx}");
                assert_eq!(an.total_flops, ev.total_flops, "{ctx}");
                assert_eq!(an.avg_power_w.to_bits(), ev.avg_power_w.to_bits(), "{ctx}");
                for ((ca, ua), (cb, ub)) in
                    an.channel_utilization.iter().zip(&ev.channel_utilization)
                {
                    assert_eq!(ca, cb, "{ctx}: channel order");
                    assert_eq!(ua.to_bits(), ub.to_bits(), "{ctx}: channel utilization");
                }
                // ...and the busy times share a closed form (event
                // accumulates t_batch by repeated addition, so compare
                // up to float associativity, not bitwise)
                assert!(
                    rel_close(an.cu_time_s, ev.cu_time_s),
                    "{ctx}: cu_time {} vs {}",
                    an.cu_time_s,
                    ev.cu_time_s
                );
                assert!(
                    rel_close(an.transfer_time_s, ev.transfer_time_s),
                    "{ctx}: transfer_time {} vs {}",
                    an.transfer_time_s,
                    ev.transfer_time_s
                );

                if ev.batches >= 12 {
                    max_gap_12 = max_gap_12.max(b.rel_gap());
                }
                if ev.batches >= 100 {
                    max_gap_100 = max_gap_100.max(b.rel_gap());
                }
                points += 1;
            }
        }
    }

    // the grid must actually have run (mapping failures don't erase it)
    assert!(points >= 45, "only {points} grid points were mappable");
    // pin the observed maxima by batch regime (the contract above is
    // the only claim for tiny-batch points — a kernel whose batch
    // swallows the workload, e.g. mass_apply at high CU counts, is
    // legitimately loose): with <= 8 CUs the contract caps >=12-batch
    // points at 9/12 and >=100-batch points well under 10%
    assert!(
        max_gap_12 <= 0.7501,
        "max rel_gap at >=12 batches drifted to {max_gap_12}"
    );
    assert!(
        max_gap_100 <= 0.10,
        "max rel_gap at >=100 batches drifted to {max_gap_100}"
    );
}

// ---------------------------------------------------------------------
// Satellite 2: the parallel timeline is bit-identical at SimResult
// level (the event.rs property test covers the Timeline level; this is
// the user-visible surface).
// ---------------------------------------------------------------------

#[test]
fn parallel_timeline_simresult_is_bit_identical_to_sequential() {
    let platform = Platform::alveo_u280();
    let mut rng = Prng::new(0xB17_1DE27);
    let mut compared = 0usize;
    for (label, src, p) in library() {
        // parallelism only engages with >= 2 CUs; 8 stresses partitioning
        for cus in [4usize, 8] {
            let Some(m) = map(&src, p, cus) else { continue };
            let est = hls::estimate(&m.spec, &platform);
            for n in [500_000u64, rng.range_u64(250_000, 6_000_000)] {
                let seq = sim::simulate_with_timeline(
                    &m.spec,
                    &est,
                    &platform,
                    n,
                    TimelineMode::Sequential,
                );
                let par = sim::simulate_with_timeline(
                    &m.spec,
                    &est,
                    &platform,
                    n,
                    TimelineMode::Parallel,
                );
                assert_bit_identical(&seq, &par, &format!("{label} × {cus}cu × {n}"));
                compared += 1;
            }
        }
    }
    assert!(compared >= 10, "only {compared} systems compared");
}

// ---------------------------------------------------------------------
// Satellite 3: regression pins — the paper-shape results the parallel
// timeline must not move.
// ---------------------------------------------------------------------

fn fig17_run(cus: usize, mode: TimelineMode) -> SimResult {
    let kernel = build_kernel("helmholtz", 11).unwrap();
    let platform = Platform::alveo_u280();
    let mut opts = OlympusOpts::fixed_point(DataType::Fx32);
    if cus > 1 {
        opts = opts.with_cus(cus);
    }
    let spec = olympus::generate(&kernel, &opts, &platform).unwrap();
    let est = hls::estimate(&spec, &platform);
    sim::simulate_with_timeline(&spec, &est, &platform, paper::N_ELEMENTS, mode)
}

#[test]
fn fig17_multi_cu_pins_hold_under_both_timelines() {
    // before/after: the scheduler change cannot move the numbers at all
    let one_seq = fig17_run(1, TimelineMode::Sequential);
    let one_par = fig17_run(1, TimelineMode::Parallel);
    let three_seq = fig17_run(3, TimelineMode::Sequential);
    let three_par = fig17_run(3, TimelineMode::Parallel);
    assert_bit_identical(&one_seq, &one_par, "fig17 1 CU");
    assert_bit_identical(&three_seq, &three_par, "fig17 3 CUs");

    // and the paper shape itself (paper_shapes::e5) holds under both
    for (one, three) in [(&one_seq, &three_seq), (&one_par, &three_par)] {
        assert!(
            three.gflops_cu > 1.3 * one.gflops_cu,
            "kernel must scale: {} vs {}",
            three.gflops_cu,
            one.gflops_cu
        );
        assert!(
            three.gflops_system < one.gflops_system * 1.1,
            "system must not: {} vs {}",
            three.gflops_system,
            one.gflops_system
        );
        assert_eq!(three.bottleneck, "pcie");
    }
}

#[test]
fn table3_mem_sharing_deltas_unchanged_by_parallel_timeline() {
    // Table 3's Mem-Sharing row is a resource result; driving the
    // evaluation through either timeline must report identical totals
    // and preserve the paper's URAM delta (240 -> 124, -48.3%).
    let kernel = build_kernel("helmholtz", 11).unwrap();
    let platform = Platform::alveo_u280();
    let totals = |opts: &OlympusOpts, mode: TimelineMode| {
        let spec = olympus::generate(&kernel, opts, &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        // force the full pipeline through the chosen scheduler; the
        // estimate used downstream is the one the sim consumed
        let _ = sim::simulate_with_timeline(&spec, &est, &platform, paper::N_ELEMENTS, mode);
        est.total
    };

    let no_seq = totals(&OlympusOpts::dataflow(1), TimelineMode::Sequential);
    let no_par = totals(&OlympusOpts::dataflow(1), TimelineMode::Parallel);
    let yes_seq = totals(&OlympusOpts::mem_sharing(), TimelineMode::Sequential);
    let yes_par = totals(&OlympusOpts::mem_sharing(), TimelineMode::Parallel);
    assert_eq!(no_seq, no_par, "timeline choice leaked into resources");
    assert_eq!(yes_seq, yes_par, "timeline choice leaked into resources");

    let uram_delta = yes_seq.uram as f64 / no_seq.uram as f64 - 1.0;
    assert!(
        (uram_delta - (-0.483)).abs() < 0.06,
        "URAM delta {uram_delta:.3} drifted from the paper's -48.3%"
    );
}
