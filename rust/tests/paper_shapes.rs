//! Integration: the paper's headline claims as executable assertions.
//! This is the "does the reproduction reproduce" suite — every claim in
//! DESIGN.md §3's shape criteria is checked here once, end to end.

use hbmflow::cli::build_kernel;
use hbmflow::datatype::DataType;
use hbmflow::hls;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::report::paper;
use hbmflow::sim::{self, SimResult};

fn run(opts: OlympusOpts, p: usize, n: u64) -> SimResult {
    let kernel = build_kernel("helmholtz", p).unwrap();
    let platform = Platform::alveo_u280();
    let spec = olympus::generate(&kernel, &opts, &platform).unwrap();
    let est = hls::estimate(&spec, &platform);
    sim::simulate(&spec, &est, &platform, n)
}

const N: u64 = paper::N_ELEMENTS;

#[test]
fn e1_fig15_full_ladder_ordering() {
    let g = |o: OlympusOpts| run(o, 11, N).gflops_system;
    let base = g(OlympusOpts::baseline());
    let db = g(OlympusOpts::double_buffering());
    let ser = g(OlympusOpts::bus_serial());
    let par = g(OlympusOpts::bus_parallel());
    let d1 = g(OlympusOpts::dataflow(1));
    let d2 = g(OlympusOpts::dataflow(2));
    let d3 = g(OlympusOpts::dataflow(3));
    let d7 = g(OlympusOpts::dataflow(7));
    // paper Fig. 15 ordering
    assert!(db >= base * 0.95, "double buffering never hurts");
    assert!(ser < db / 2.0, "serial degrades ~3x");
    assert!(par / ser > 3.0 && par / ser < 5.0, "parallel ~3.9x serial");
    assert!(d1 > 2.5 * par, "dataflow-1 ~3.7x");
    assert!(d2 > 1.3 * d1, "dataflow-2 ~1.7x over dataflow-1");
    assert!(d3 <= 1.05 * d2, "dataflow-3 no better");
    assert!(d7 > d2 && d7 > 4.0 * par, "dataflow-7 ~4x over bus opt");
    // magnitudes within 2x of the paper
    assert!((base / 2.903 - 1.0).abs() < 1.0);
    assert!((d7 / 43.410 - 1.0).abs() < 1.0);
}

#[test]
fn e2_table2_op_counts_and_efficiency_band() {
    let kernel = build_kernel("helmholtz", 11).unwrap();
    let platform = Platform::alveo_u280();
    for (i, opts) in [
        OlympusOpts::baseline(),
        OlympusOpts::double_buffering(),
        OlympusOpts::bus_serial(),
        OlympusOpts::bus_parallel(),
        OlympusOpts::dataflow(1),
        OlympusOpts::dataflow(2),
        OlympusOpts::dataflow(3),
        OlympusOpts::dataflow(7),
    ]
    .into_iter()
    .enumerate()
    {
        let spec = olympus::generate(&kernel, &opts, &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        assert_eq!(est.ops(), paper::TABLE2[i].ops, "{}", opts.label());
        let r = sim::simulate(&spec, &est, &platform, N);
        assert!(
            (0.25..1.0).contains(&r.efficiency_vs_ideal),
            "{}: efficiency {}",
            opts.label(),
            r.efficiency_vs_ideal
        );
    }
}

#[test]
fn e4_fig16_datatype_speedups() {
    let d = run(OlympusOpts::dataflow(7), 11, N).gflops_system;
    let f64_ = run(OlympusOpts::fixed_point(DataType::Fx64), 11, N).gflops_system;
    let f32_ = run(OlympusOpts::fixed_point(DataType::Fx32), 11, N).gflops_system;
    assert!(f64_ / d > 1.0 && f64_ / d < 1.6, "fx64 {:.2}x (paper 1.19)", f64_ / d);
    assert!(f32_ / d > 1.7 && f32_ / d < 3.2, "fx32 {:.2}x (paper 2.37)", f32_ / d);
    // the headline: ~103 GOPS within 40%
    assert!((f32_ / 103.0 - 1.0).abs() < 0.4, "fx32 {f32_}");
}

#[test]
fn e5_fig17_replication_is_pcie_bound() {
    let one = run(OlympusOpts::fixed_point(DataType::Fx32), 11, N);
    let three = run(OlympusOpts::fixed_point(DataType::Fx32).with_cus(3), 11, N);
    assert!(three.gflops_cu > 1.3 * one.gflops_cu, "kernel scales");
    assert!(three.gflops_system < one.gflops_system * 1.1, "system does not");
    assert_eq!(three.bottleneck, "pcie");
}

#[test]
fn e6_fig18_efficiency_ordering() {
    let e = |o: OlympusOpts| run(o, 11, N).efficiency_gflops_w;
    let d = e(OlympusOpts::dataflow(7));
    let f64_ = e(OlympusOpts::fixed_point(DataType::Fx64));
    let f32_ = e(OlympusOpts::fixed_point(DataType::Fx32));
    assert!(f64_ > d);
    assert!(f32_ > f64_);
    // ~4 GOPS/W headline and ~24.5x Intel
    assert!((2.0..7.0).contains(&f32_), "{f32_}");
    let intel = paper::intel_optimized_gflops("helmholtz") / 100.0;
    assert!((10.0..45.0).contains(&(f32_ / intel)));
}

#[test]
fn e7_fig19_kernels_beat_cpu_baselines() {
    // simulated FPGA vs the paper's Intel numbers (CPU measurement is
    // covered by the fig19 bench; here only deterministic quantities)
    let helm = run(OlympusOpts::dataflow(7), 11, N).gflops_system;
    let vs_intel = helm / paper::intel_optimized_gflops("helmholtz");
    assert!((1.2..6.0).contains(&vs_intel), "{vs_intel} (paper 2.7)");

    // interpolation: optimized vs baseline FPGA must show the 36-160x
    // pattern's precondition — optimization helps by >3x
    let k = build_kernel("interpolation", 11).unwrap();
    let platform = Platform::alveo_u280();
    let b = {
        let spec = olympus::generate(&k, &OlympusOpts::baseline(), &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        sim::simulate(&spec, &est, &platform, N).gflops_system
    };
    let o = {
        let spec = olympus::generate(&k, &OlympusOpts::dataflow(3), &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        sim::simulate(&spec, &est, &platform, N).gflops_system
    };
    assert!(o > 3.0 * b, "interpolation opt {o} vs base {b}");
}

#[test]
fn e8_flop_model_eq2() {
    assert_eq!(build_kernel("helmholtz", 11).unwrap().flops_per_element(), 177_023);
    assert_eq!(build_kernel("helmholtz", 7).unwrap().flops_per_element(), 29_155);
}

#[test]
fn p7_replicates_more_cus_than_p11() {
    // Paper Table 5: p=7 fits more CUs (fx32: 4 vs 3).
    let platform = Platform::alveo_u280();
    let fits = |p: usize, cus: usize| {
        let k = build_kernel("helmholtz", p).unwrap();
        let o = OlympusOpts::fixed_point(DataType::Fx32).with_cus(cus);
        let spec = olympus::generate(&k, &o, &platform).unwrap();
        hls::estimate(&spec, &platform)
            .total
            .fits_in(&platform.total_resources())
    };
    let max_p11 = (1..=8).take_while(|&c| fits(11, c)).count();
    let max_p7 = (1..=8).take_while(|&c| fits(7, c)).count();
    assert!(max_p7 > max_p11, "p7 {max_p7} vs p11 {max_p11}");
    assert!(max_p11 >= 2, "paper fits at least 3 for fx32 p=11");
}
