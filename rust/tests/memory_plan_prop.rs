//! Property suite for the unified on-chip memory plan
//! (`mnemosyne::plan`), over *randomized affine kernels* — not just the
//! paper's operators — plus the Table 3 "Mem Sharing" regression pins.
//!
//! Properties (ISSUE 4):
//!  * every plan is conflict-free: no two lifetime-overlapping buffers
//!    share a bank, and bank read ports cover the resident access
//!    degree (at the uncapped default);
//!  * `shared_words() <= unshared_words()`;
//!  * plans are deterministic across runs;
//!  * a partition cap bounds the conflict factor by `ceil(trip / cap)`
//!    and never produces conflicts past that bound.
//!
//! Seeds are pinned by `util::prop` (fixed base seed), so CI replays
//! the exact same kernels every run.

use hbmflow::datatype::DataType;
use hbmflow::dsl;
use hbmflow::hls;
use hbmflow::ir::affine::{Buffer, BufKind, EwOp, Kernel, LoopNest, NestKind};
use hbmflow::ir::{lower, rewrite, schedule, teil};
use hbmflow::mnemosyne::{self, CacheScheme, PlanOpts};
use hbmflow::olympus::{generate, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::util::prng::Prng;
use hbmflow::util::prop;

/// A random valid affine kernel: a chain of contraction / elementwise /
/// permute nests over `[d, d, d]` tensors with a `[d, d]` operator
/// matrix, with some write-only (dead) temps, an optional unused temp
/// buffer, and a final contraction into the output.
fn random_kernel(rng: &mut Prng) -> Kernel {
    let d = rng.range_usize(2, 6);
    let tensor = vec![d, d, d];
    let mut buffers = vec![
        Buffer {
            name: "m".into(),
            shape: vec![d, d],
            kind: BufKind::Input,
        },
        Buffer {
            name: "x".into(),
            shape: tensor.clone(),
            kind: BufKind::Input,
        },
    ];
    let mut nests: Vec<LoopNest> = Vec::new();
    // tensor-shaped buffers a later nest may read
    let mut live: Vec<usize> = vec![1];
    let n_nests = rng.range_usize(2, 6);
    for ni in 0..n_nests {
        let wid = buffers.len();
        buffers.push(Buffer {
            name: format!("t{ni}"),
            shape: tensor.clone(),
            kind: BufKind::Temp,
        });
        let src = *rng.choose(&live);
        let (kind, reads, red) = match rng.range_usize(0, 2) {
            0 => (
                NestKind::Contraction {
                    matrix: 0,
                    transpose: rng.bool(),
                    mode: rng.range_usize(0, 2),
                },
                vec![0, src],
                d,
            ),
            1 => {
                let other = *rng.choose(&live);
                let mut reads = vec![src];
                if other != src {
                    reads.push(other);
                }
                (NestKind::Elementwise(EwOp::Mul), reads, 1)
            }
            _ => (NestKind::Permute { from: 0, to: 2 }, vec![src], 1),
        };
        nests.push(LoopNest {
            name: format!("n{ni}"),
            out_trips: tensor.clone(),
            red_trip: red,
            reads,
            write: wid,
            kind,
            stmt: ni,
        });
        // a write kept out of `live` is a dead (write-only) temp
        if rng.bool() {
            live.push(wid);
        }
    }
    if rng.bool() {
        // an unused temp: never written, never read — must not break
        // the planner (regression for the SharingPlan placement check)
        buffers.push(Buffer {
            name: "ghost".into(),
            shape: tensor.clone(),
            kind: BufKind::Temp,
        });
    }
    let out = buffers.len();
    buffers.push(Buffer {
        name: "y".into(),
        shape: tensor.clone(),
        kind: BufKind::Output,
    });
    let src = *rng.choose(&live);
    nests.push(LoopNest {
        name: "out".into(),
        out_trips: tensor,
        red_trip: d,
        reads: vec![0, src],
        write: out,
        kind: NestKind::Contraction {
            matrix: 0,
            transpose: false,
            mode: 0,
        },
        stmt: n_nests,
    });
    let k = Kernel {
        name: "rand".into(),
        buffers,
        nests,
    };
    k.validate().expect("generator emits valid kernels");
    k
}

/// Random plan inputs for one kernel.
fn random_plan(
    rng: &mut Prng,
    k: &Kernel,
) -> (mnemosyne::MemoryPlan, schedule::Schedule, bool, PlanOpts) {
    let groups = rng.range_usize(1, k.nests.len());
    let s = schedule::fixed(k, groups).unwrap();
    let dataflow = groups > 1 || rng.bool();
    let d = hbmflow::ir::access::max_read_degree(k);
    let opts = PlanOpts {
        sharing: rng.bool(),
        partition_cap: if rng.bool() {
            Some(rng.range_usize(1, d))
        } else {
            None
        },
        fifo_depth: if rng.bool() { Some(64) } else { None },
        cache: CacheScheme::Bypass,
    };
    let word_bytes = if rng.bool() { 8 } else { 4 };
    let mp = mnemosyne::plan(k, &s, dataflow, word_bytes, &opts);
    (mp, s, dataflow, opts)
}

#[test]
fn prop_plans_are_conflict_free_and_validated() {
    prop::check("memory plan soundness", 48, |rng| {
        let k = random_kernel(rng);
        let (mp, _, _, _) = random_plan(rng, &k);
        mp.validate(&k)?;
        // conflict-free by construction at the uncapped default
        if mp.partition_cap.is_none() {
            for a in &mp.arrays {
                prop::assert_prop(
                    a.read_ports() >= a.access_degree,
                    format!("{} ports < degree {}", a.read_ports(), a.access_degree),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_shared_words_never_exceed_unshared() {
    prop::check("sharing never grows storage", 48, |rng| {
        let k = random_kernel(rng);
        let (mp, _, _, _) = random_plan(rng, &k);
        prop::assert_prop(
            mp.shared_words() <= mp.unshared_words(&k),
            format!("{} > {}", mp.shared_words(), mp.unshared_words(&k)),
        )
    });
}

#[test]
fn prop_plans_are_deterministic() {
    prop::check("plan determinism", 24, |rng| {
        let k = random_kernel(rng);
        let groups = rng.range_usize(1, k.nests.len());
        let s = schedule::fixed(&k, groups).unwrap();
        let opts = PlanOpts {
            sharing: rng.bool(),
            partition_cap: if rng.bool() { Some(2) } else { None },
            fifo_depth: None,
            cache: CacheScheme::Bypass,
        };
        let a = mnemosyne::plan(&k, &s, groups > 1, 8, &opts);
        let b = mnemosyne::plan(&k, &s, groups > 1, 8, &opts);
        prop::assert_prop(a == b, "same inputs, different plans".to_string())
    });
}

#[test]
fn prop_conflict_factor_is_one_uncapped_and_bounded_capped() {
    prop::check("conflict factor bounds", 48, |rng| {
        let k = random_kernel(rng);
        let (mp, s, dataflow, opts) = random_plan(rng, &k);
        let multi = dataflow && s.num_groups() > 1;
        for (gi, g) in s.groups.iter().enumerate() {
            let plan_group = if multi { Some(gi) } else { None };
            for ni in g.nests() {
                let cf = mp.nest_conflict_factor(&k, ni, plan_group);
                match opts.partition_cap {
                    None => prop::assert_prop(
                        cf == 1,
                        format!("uncapped nest {ni} stalls x{cf}"),
                    )?,
                    Some(c) => {
                        let trip = k.nests[ni].red_trip as u64;
                        let bound = trip.div_ceil(c.max(1) as u64);
                        prop::assert_prop(
                            cf <= bound,
                            format!("nest {ni}: {cf} > ceil({trip}/{c})"),
                        )?;
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_generated_specs_carry_sound_plans_end_to_end() {
    // the full olympus path on the paper kernel under random memory-axis
    // options: spec validation (which validates the plan) plus the
    // stall/cap acceptance invariant
    let prog = dsl::parse(&dsl::inverse_helmholtz_source(7)).unwrap();
    let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
    let k = lower::lower_kernel(&m, "helmholtz").unwrap();
    let platform = Platform::alveo_u280();
    prop::check("olympus memory axis", 12, |rng| {
        let mut opts = if rng.bool() {
            OlympusOpts::mem_sharing()
        } else {
            OlympusOpts::dataflow(rng.range_usize(1, 7))
        };
        let cap = if rng.bool() {
            Some(rng.range_usize(1, 9))
        } else {
            None
        };
        opts.partition_cap = cap;
        let spec = generate(&k, &opts, &platform)?;
        spec.validate(&platform)?;
        let est = hls::estimate(&spec, &platform);
        let r = hbmflow::sim::simulate(&spec, &est, &platform, 50_000);
        let capped_below_trip = cap.is_some_and(|c| c < 7);
        prop::assert_prop(
            (r.conflict_stalls > 0) == capped_below_trip,
            format!("cap {cap:?} -> stalls {}", r.conflict_stalls),
        )
    });
}

// ---------------------------------------------------------------------
// Table 3 "Mem Sharing" regression (satellite): pin the deltas so the
// resource model cannot silently drift.
// ---------------------------------------------------------------------

fn helmholtz(p: usize) -> Kernel {
    let prog = dsl::parse(&dsl::inverse_helmholtz_source(p)).unwrap();
    let m = rewrite::optimize(teil::from_ast(&prog).unwrap());
    lower::lower_kernel(&m, "helmholtz").unwrap()
}

#[test]
fn table3_mem_sharing_deltas_stay_pinned() {
    // Paper Table 3, 1-CU dataflow design: Mem Sharing takes URAM
    // 240 -> 124 (-48.3%) and BRAM -14.5%. The model reproduces the
    // URAM delta mechanistically (two shared banks instead of six
    // private temp arrays); its BRAM on this row is the constant AXI
    // infrastructure floor (the paper's BRAM saving comes from P&R-level
    // packing the model books as that fitted constant), so the pin for
    // BRAM is "never increases, never drops past the paper's band".
    let k = helmholtz(11);
    let platform = Platform::alveo_u280();
    let total = |opts: &OlympusOpts| {
        let spec = generate(&k, opts, &platform).unwrap();
        hls::estimate(&spec, &platform).total
    };
    let no = total(&OlympusOpts::dataflow(1));
    let yes = total(&OlympusOpts::mem_sharing());

    let uram_delta = yes.uram as f64 / no.uram as f64 - 1.0;
    assert!(
        (uram_delta - (-0.483)).abs() < 0.06,
        "URAM delta {uram_delta:.3} drifted from the paper's -48.3%"
    );
    // absolute counts stay in the paper's neighborhood
    assert!(
        (no.uram as f64 - 240.0).abs() / 240.0 < 0.20,
        "unshared URAM {} vs paper 240",
        no.uram
    );
    assert!(
        (yes.uram as f64 - 124.0).abs() / 124.0 < 0.20,
        "shared URAM {} vs paper 124",
        yes.uram
    );

    let bram_delta = yes.bram as f64 / no.bram as f64 - 1.0;
    assert!(bram_delta <= 0.0, "sharing must never cost BRAM");
    assert!(
        bram_delta >= -0.25,
        "BRAM delta {bram_delta:.3} overshoots the paper's -14.5% band"
    );

    // plan-level pin: six p^3 temps collapse into exactly two banks
    let spec = generate(&k, &OlympusOpts::mem_sharing(), &platform).unwrap();
    let sp = spec.memory.sharing.as_ref().unwrap();
    assert_eq!(sp.banks.len(), 2, "left-edge coloring of the temp chain");
    assert_eq!(
        3 * sp.shared_words(),
        sp.unshared_words(&k),
        "6 temps x p^3 share 2 banks x p^3"
    );
}

#[test]
fn table3_sharing_leaves_the_datapath_alone() {
    let k = helmholtz(11);
    let platform = Platform::alveo_u280();
    let mk = |opts: &OlympusOpts| {
        let spec = generate(&k, opts, &platform).unwrap();
        hls::estimate(&spec, &platform)
    };
    let no = mk(&OlympusOpts::dataflow(1));
    let yes = mk(&OlympusOpts::mem_sharing());
    assert_eq!(no.total.dsp, yes.total.dsp);
    assert_eq!(no.ops(), yes.ops());
    // and the fixed-point path keeps its own invariant: fx32 arrays are
    // all BRAM/LUTRAM, so sharing moves BRAM instead of URAM there
    let mut fx = OlympusOpts::mem_sharing();
    fx.dtype = DataType::Fx32;
    let fx_no = {
        let mut o = OlympusOpts::dataflow(1);
        o.dtype = DataType::Fx32;
        mk(&o)
    };
    let fx_yes = mk(&fx);
    assert_eq!(fx_yes.total.uram, 0);
    assert!(fx_yes.total.bram < fx_no.total.bram);
}
