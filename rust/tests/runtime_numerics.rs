//! Integration: PJRT runtime numerics vs the native tensor oracle across
//! every kernel family and dtype variant. Skips cleanly when artifacts
//! have not been built (`make artifacts`).

use hbmflow::runtime::Runtime;
use hbmflow::util::prng::Prng;
use hbmflow::util::tensor::Tensor;

fn runtime() -> Option<Runtime> {
    match Runtime::from_default_dir() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e}");
            None
        }
    }
}

fn helmholtz_oracle(s: &Tensor, d: &Tensor, u: &Tensor) -> Tensor {
    let st = transpose(s);
    let t = u.mode_apply(s, 0).mode_apply(s, 1).mode_apply(s, 2);
    let r = d.zip(&t, |a, b| a * b);
    r.mode_apply(&st, 0).mode_apply(&st, 1).mode_apply(&st, 2)
}

fn transpose(t: &Tensor) -> Tensor {
    let (r, c) = (t.shape()[0], t.shape()[1]);
    let mut out = Tensor::zeros(&[c, r]);
    for i in 0..r {
        for j in 0..c {
            out.set(&[j, i], t.get(&[i, j]));
        }
    }
    out
}

#[test]
fn every_f64_helmholtz_artifact_matches_oracle() {
    let Some(mut rt) = runtime() else { return };
    let names: Vec<String> = rt
        .manifest
        .artifacts
        .iter()
        .filter(|a| a.kernel == "helmholtz" && a.dtype == "f64")
        .map(|a| a.name.clone())
        .collect();
    assert!(!names.is_empty());
    for name in names {
        let meta = rt.meta(&name).unwrap().clone();
        let (p, b) = (meta.p, meta.batch);
        let mut rng = Prng::new(0xBEEF ^ p as u64 ^ (b as u64) << 8);
        let s = Tensor::random(&[p, p], &mut rng);
        let d = Tensor::random(&[b, p, p, p], &mut rng);
        let u = Tensor::random(&[b, p, p, p], &mut rng);
        let outs = rt
            .run_f64(&name, &[s.data().to_vec(), d.data().to_vec(), u.data().to_vec()])
            .unwrap();
        let v = &outs[0];
        let block = p * p * p;
        for e in 0..b {
            let de = Tensor::from_vec(&[p, p, p], d.data()[e * block..(e + 1) * block].to_vec());
            let ue = Tensor::from_vec(&[p, p, p], u.data()[e * block..(e + 1) * block].to_vec());
            let want = helmholtz_oracle(&s, &de, &ue);
            for (i, &wv) in want.data().iter().enumerate() {
                let got = v[e * block + i];
                assert!(
                    (got - wv).abs() < 1e-9 * wv.abs().max(1.0),
                    "{name} e{e} i{i}: {got} vs {wv}"
                );
            }
        }
    }
}

#[test]
fn pallas_and_ref_variants_agree() {
    let Some(mut rt) = runtime() else { return };
    let p = 11;
    let pal = rt.manifest.find("helmholtz", p, "f64", "pallas").unwrap().clone();
    let refa = rt.manifest.find("helmholtz", p, "f64", "ref").unwrap().clone();
    assert_eq!(pal.batch, refa.batch);
    let b = pal.batch;
    let mut rng = Prng::new(17);
    let s = rng.unit_vec(p * p);
    let d = rng.unit_vec(b * p * p * p);
    let u = rng.unit_vec(b * p * p * p);
    let v1 = rt
        .run_f64(&pal.name, &[s.clone(), d.clone(), u.clone()])
        .unwrap();
    let v2 = rt.run_f64(&refa.name, &[s, d, u]).unwrap();
    for (a, b_) in v1[0].iter().zip(&v2[0]) {
        assert!((a - b_).abs() < 1e-10, "{a} vs {b_}");
    }
}

#[test]
fn interpolation_artifact_matches_oracle() {
    let Some(mut rt) = runtime() else { return };
    let meta = rt.manifest.find("interpolation", 11, "f64", "pallas").unwrap().clone();
    let (n, b) = (11usize, meta.batch);
    let mut rng = Prng::new(23);
    let a = Tensor::random(&[n, n], &mut rng);
    let u = Tensor::random(&[b, n, n, n], &mut rng);
    let outs = rt
        .run_f64(&meta.name, &[a.data().to_vec(), u.data().to_vec()])
        .unwrap();
    let block = n * n * n;
    for e in 0..b {
        let ue = Tensor::from_vec(&[n, n, n], u.data()[e * block..(e + 1) * block].to_vec());
        let want = ue.mode_apply(&a, 0).mode_apply(&a, 1).mode_apply(&a, 2);
        for (i, &wv) in want.data().iter().enumerate() {
            let got = outs[0][e * block + i];
            assert!((got - wv).abs() < 1e-10, "e{e} i{i}");
        }
    }
}

#[test]
fn gradient_artifact_matches_oracle() {
    let Some(mut rt) = runtime() else { return };
    let meta = rt.manifest.find("gradient", 8, "f64", "pallas").unwrap().clone();
    let b = meta.batch;
    let (nx, ny, nz) = (8usize, 7, 6);
    let mut rng = Prng::new(29);
    let dx = Tensor::random(&[nx, nx], &mut rng);
    let dy = Tensor::random(&[ny, ny], &mut rng);
    let dz = Tensor::random(&[nz, nz], &mut rng);
    let u = Tensor::random(&[b, nx, ny, nz], &mut rng);
    let outs = rt
        .run_f64(
            &meta.name,
            &[
                dx.data().to_vec(),
                dy.data().to_vec(),
                dz.data().to_vec(),
                u.data().to_vec(),
            ],
        )
        .unwrap();
    let block = nx * ny * nz;
    for e in 0..b.min(4) {
        let ue = Tensor::from_vec(&[nx, ny, nz], u.data()[e * block..(e + 1) * block].to_vec());
        let wants = [
            ue.mode_apply(&dx, 0),
            ue.mode_apply(&dy, 1),
            ue.mode_apply(&dz, 2),
        ];
        for (o, want) in outs.iter().zip(&wants) {
            for (i, &wv) in want.data().iter().enumerate() {
                assert!((o[e * block + i] - wv).abs() < 1e-10, "e{e} i{i}");
            }
        }
    }
}

#[test]
fn fx_artifacts_quantize_but_stay_close() {
    let Some(mut rt) = runtime() else { return };
    let p = 11;
    let b = 32;
    let mut rng = Prng::new(31);
    // scaled S keeps intermediates in the fixed-point range
    let mut s = rng.unit_vec(p * p);
    for x in &mut s {
        *x /= p as f64;
    }
    let d = rng.unit_vec(b * p * p * p);
    let u = rng.unit_vec(b * p * p * p);
    let exact = rt
        .run_f64("helmholtz_p11_f64_b32", &[s.clone(), d.clone(), u.clone()])
        .unwrap();
    let fx64 = rt
        .run_f64("helmholtz_p11_fx64_b32", &[s.clone(), d.clone(), u.clone()])
        .unwrap();
    let fx32 = rt.run_f64("helmholtz_p11_fx32_b32", &[s, d, u]).unwrap();
    let mse = |a: &[f64], b: &[f64]| {
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / a.len() as f64
    };
    let m64 = mse(&exact[0], &fx64[0]);
    let m32 = mse(&exact[0], &fx32[0]);
    assert!(m64 > 0.0 && m64 < 1e-20, "fx64 mse {m64}");
    assert!(m32 > 1e-18 && m32 < 1e-10, "fx32 mse {m32}");
    assert!(m32 / m64 > 1e6, "ratio {}", m32 / m64);
}
