//! Frontier-equivalence harness for the budget-aware search engine
//! (`hbmflow dse --strategy …`, DESIGN.md §2.8).
//!
//! The claims under test, in order of importance:
//!
//!  1. **Frontier equivalence** — the streaming strategy with the
//!     analytic prune produces a Pareto frontier *bit-identical* to the
//!     eager exhaustive explorer at `Fidelity::Exact`, on randomized
//!     small spaces and on the (narrowed-degree) default helmholtz
//!     axes.
//!  2. **Memory boundedness** — a stream sweep never materializes the
//!     cross product: peak resident evaluations stay O(batch +
//!     frontier) while hundreds of candidates are considered.
//!  3. **Resumability** — a sweep killed at a checkpoint boundary and
//!     resumed in a fresh session reproduces the uninterrupted frontier
//!     exactly, and `Session::stats().eval_calls` proves no point is
//!     evaluated twice across the kill/resume boundary.
//!  4. **Determinism** — the same seed yields byte-identical reports
//!     across repeated runs and across worker-thread counts.
//!  5. **Honest sampling** — random/LHS/hill-climb results are
//!     feasible, mutually non-dominated, within budget, drawn from the
//!     space, and bit-identical to the exhaustive evaluation of the
//!     same points.
//!
//! "Bit-identical" throughout means Debug-formatting equality of the
//! full evaluation (Rust formats f64 shortest-round-trip, so equal
//! strings mean equal bits in every float).

use std::collections::{HashMap, HashSet};

use hbmflow::datatype::DataType;
use hbmflow::dse::{
    self, explore_in_with, search_in, Fidelity, SearchConfig, SearchSpace,
    Strategy,
};
use hbmflow::flow::Session;
use hbmflow::olympus::BusMode;
use hbmflow::platform::Platform;
use hbmflow::util::prng::Prng;

const ELEMENTS: u64 = 20_000;

fn fresh_session() -> Session {
    Session::new(Platform::alveo_u280())
}

/// Frontier as sorted (fingerprint, exact Debug of the evaluation)
/// pairs — equality is bit-identity of every number in every member.
fn frontier_bits(ex: &dse::Exploration) -> Vec<(String, String)> {
    let mut rows: Vec<(String, String)> = ex
        .frontier
        .iter()
        .map(|&i| {
            let o = &ex.outcomes[i];
            (o.point.fingerprint(), format!("{:?}", o.result))
        })
        .collect();
    rows.sort();
    rows
}

/// Random non-empty subsequence of `all` (order preserved).
fn pick<T: Clone>(rng: &mut Prng, all: &[T]) -> Vec<T> {
    let mut out: Vec<T> = all
        .iter()
        .filter(|_| rng.range_usize(0, 1) == 1)
        .cloned()
        .collect();
    if out.is_empty() {
        out.push(all[rng.range_usize(0, all.len() - 1)].clone());
    }
    out
}

/// A randomized small helmholtz space, capped so the eager exact
/// reference stays affordable in debug builds.
fn random_space(rng: &mut Prng) -> SearchSpace {
    let mut s = SearchSpace::default_for("helmholtz");
    s.degrees = vec![[7usize, 11][rng.range_usize(0, 1)]];
    s.dtypes = pick(rng, &[DataType::F64, DataType::Fx32]);
    s.cu_counts = pick(rng, &[1, 2]);
    s.dataflow = pick(rng, &[None, Some(1), Some(2), Some(7)]);
    s.double_buffering = pick(rng, &[false, true]);
    s.bus_modes = pick(rng, &[BusMode::Narrow64, BusMode::Wide256Parallel]);
    s.mem_sharing = pick(rng, &[false, true]);
    s.fifo_depths = pick(rng, &[None, Some(64)]);
    s.partition_caps = pick(rng, &[None, Some(4)]);
    // cap the raw size by collapsing the longest axis until affordable
    while s.enumerate().len() > 48 {
        let lens = [
            s.dtypes.len(),
            s.cu_counts.len(),
            s.dataflow.len(),
            s.double_buffering.len(),
            s.bus_modes.len(),
            s.mem_sharing.len(),
            s.fifo_depths.len(),
            s.partition_caps.len(),
        ];
        let ax = (0..lens.len()).max_by_key(|&i| lens[i]).unwrap();
        match ax {
            0 => s.dtypes.truncate(1),
            1 => s.cu_counts.truncate(1),
            2 => s.dataflow.truncate(1),
            3 => s.double_buffering.truncate(1),
            4 => s.bus_modes.truncate(1),
            5 => s.mem_sharing.truncate(1),
            6 => s.fifo_depths.truncate(1),
            _ => s.partition_caps.truncate(1),
        }
    }
    s
}

/// The fixed 24-point space the resumability/determinism tests sweep
/// (6 batches of 4): all points structurally coherent, one CU.
fn fixed_space() -> SearchSpace {
    let mut s = SearchSpace::default_for("helmholtz");
    s.degrees = vec![11];
    s.dtypes = vec![DataType::F64, DataType::Fx32];
    s.cu_counts = vec![1];
    s.dataflow = vec![None, Some(2), Some(7)];
    s.double_buffering = vec![false, true];
    s.bus_modes = vec![BusMode::Narrow64, BusMode::Wide256Parallel];
    s.mem_sharing = vec![false];
    s.fifo_depths = vec![None];
    s
}

#[test]
fn stream_frontier_is_bit_identical_to_exact_eager_on_random_spaces() {
    let mut rng = Prng::new(0xD5E7);
    for round in 0..4 {
        let space = random_space(&mut rng);
        let exact = explore_in_with(
            &fresh_session(),
            &space,
            ELEMENTS,
            Some(2),
            Fidelity::Exact,
        )
        .unwrap();
        // small batch so multi-batch pruning against the incremental
        // frontier is actually exercised
        let cfg = SearchConfig {
            batch: 5,
            threads: Some(2),
            ..SearchConfig::default()
        };
        let swept = search_in(&fresh_session(), &space, ELEMENTS, &cfg).unwrap();
        let st = swept.stats.expect("search results carry stats");
        assert!(st.complete, "round {round}");
        assert_eq!(
            st.considered,
            exact.outcomes.len(),
            "round {round}: stream considers exactly the eager sequence"
        );
        assert_eq!(
            frontier_bits(&swept),
            frontier_bits(&exact),
            "round {round}: frontier bit-identical"
        );
        assert!(st.exact_sims <= st.considered, "round {round}");
    }
}

#[test]
fn default_axes_stream_is_memory_bounded_and_matches_exact() {
    // The full default helmholtz option axes; degrees/dtypes narrowed
    // so the eager exact reference stays affordable in debug builds.
    // Streaming ≡ eager over the COMPLETE default space (both degrees,
    // all four dtypes) is pinned at the enumeration level in
    // src/dse/space.rs without paying for simulations.
    let mut space = SearchSpace::default_for("helmholtz");
    space.degrees = vec![7];
    space.dtypes = vec![DataType::F64, DataType::Fx32];
    let exact =
        explore_in_with(&fresh_session(), &space, ELEMENTS, None, Fidelity::Exact)
            .unwrap();
    let cfg = SearchConfig {
        batch: 32,
        ..SearchConfig::default()
    };
    let swept = search_in(&fresh_session(), &space, ELEMENTS, &cfg).unwrap();
    let st = swept.stats.unwrap();
    assert_eq!(st.considered, exact.outcomes.len());
    assert!(st.considered > 150, "a real multi-batch space: {}", st.considered);
    assert_eq!(frontier_bits(&swept), frontier_bits(&exact));
    // the cross product is never materialized: resident evaluations
    // stay O(batch + frontier) however many candidates go by
    assert!(
        st.peak_resident <= 2 * cfg.batch + st.frontier_peak,
        "peak {} vs batch {} + frontier peak {}",
        st.peak_resident,
        cfg.batch,
        st.frontier_peak
    );
    assert!(
        st.peak_resident < st.considered / 2,
        "peak {} for {} considered",
        st.peak_resident,
        st.considered
    );
    assert_eq!(
        swept.outcomes.len(),
        swept.frontier.len(),
        "only frontier members stay resident"
    );
    assert!(st.pruned > 0, "the analytic screen did prove something");
}

#[test]
fn sampling_strategies_are_honest_subsets_of_the_space() {
    let space = fixed_space();
    let exact = explore_in_with(
        &fresh_session(),
        &space,
        ELEMENTS,
        Some(2),
        Fidelity::Exact,
    )
    .unwrap();
    let exact_bits: HashMap<String, String> = exact
        .outcomes
        .iter()
        .map(|o| (o.point.fingerprint(), format!("{:?}", o.result)))
        .collect();
    for strategy in [Strategy::Random, Strategy::Lhs] {
        let cfg = SearchConfig {
            strategy,
            budget: Some(12),
            seed: 5,
            batch: 4,
            threads: Some(2),
            ..SearchConfig::default()
        };
        let ex = search_in(&fresh_session(), &space, ELEMENTS, &cfg).unwrap();
        let st = ex.stats.unwrap();
        assert!(st.complete, "{strategy:?}");
        assert!(
            st.considered > 0 && st.considered <= 12,
            "{strategy:?}: {} considered",
            st.considered
        );
        assert!(!ex.frontier.is_empty(), "{strategy:?}");
        // every frontier member: drawn from the space, feasible, and
        // bit-identical to the exhaustive evaluation of the same point
        for &i in &ex.frontier {
            let o = &ex.outcomes[i];
            let fp = o.point.fingerprint();
            assert!(o.is_feasible(), "{strategy:?}: {fp}");
            let reference = exact_bits
                .get(&fp)
                .unwrap_or_else(|| panic!("{strategy:?}: {fp} not in space"));
            assert_eq!(&format!("{:?}", o.result), reference, "{strategy:?}");
        }
        // mutually non-dominated
        for &a in &ex.frontier {
            for &b in &ex.frontier {
                if a != b {
                    let va = dse::pareto::objectives(
                        ex.outcomes[a].result.as_ref().unwrap(),
                    );
                    let vb = dse::pareto::objectives(
                        ex.outcomes[b].result.as_ref().unwrap(),
                    );
                    assert!(!dse::dominates(&va, &vb), "{strategy:?}");
                }
            }
        }
    }
}

#[test]
fn hillclimb_respects_budget_and_returns_non_dominated_feasible_points() {
    let space = fixed_space();
    let cfg = SearchConfig {
        strategy: Strategy::HillClimb,
        budget: Some(14),
        seed: 3,
        batch: 4,
        threads: Some(2),
        ..SearchConfig::default()
    };
    let ex = search_in(&fresh_session(), &space, ELEMENTS, &cfg).unwrap();
    let st = ex.stats.unwrap();
    assert!(st.complete);
    assert!(
        st.considered > 0 && st.considered <= 14,
        "{} considered",
        st.considered
    );
    assert!(!ex.frontier.is_empty());
    for &i in &ex.frontier {
        assert!(ex.outcomes[i].is_feasible());
    }
    for &a in &ex.frontier {
        for &b in &ex.frontier {
            if a != b {
                let va =
                    dse::pareto::objectives(ex.outcomes[a].result.as_ref().unwrap());
                let vb =
                    dse::pareto::objectives(ex.outcomes[b].result.as_ref().unwrap());
                assert!(!dse::dominates(&va, &vb));
            }
        }
    }
}

#[test]
fn killed_sweep_resumes_to_the_uninterrupted_frontier_without_reevaluation() {
    let space = fixed_space(); // 24 points = 6 batches of 4
    let ck = std::env::temp_dir().join("hbmflow_dse_search_resume_ck.json");
    std::fs::remove_file(&ck).ok();
    let base = SearchConfig {
        batch: 4,
        threads: Some(2),
        ..SearchConfig::default()
    };

    // the uninterrupted reference, in its own session
    let sess_full = fresh_session();
    let full = search_in(&sess_full, &space, ELEMENTS, &base).unwrap();
    let e_full = sess_full.stats().eval_calls;
    assert!(full.stats.unwrap().complete);

    // killed at a checkpoint boundary after two batches
    let sess1 = fresh_session();
    let cfg_kill = SearchConfig {
        checkpoint: Some(ck.clone()),
        stop_after: Some(2),
        ..base.clone()
    };
    let paused = search_in(&sess1, &space, ELEMENTS, &cfg_kill).unwrap();
    let st1 = paused.stats.unwrap();
    assert!(!st1.complete, "paused mid-sweep");
    assert_eq!(st1.considered, 8, "two batches of four");
    let e1 = sess1.stats().eval_calls;

    // resumed in a FRESH session — nothing cached, only the checkpoint
    let sess2 = fresh_session();
    let cfg_resume = SearchConfig {
        checkpoint: Some(ck.clone()),
        ..base.clone()
    };
    let resumed = search_in(&sess2, &space, ELEMENTS, &cfg_resume).unwrap();
    let st2 = resumed.stats.unwrap();
    assert!(st2.complete);
    assert_eq!(st2.resumed_from, Some(8), "restart at the stored cursor");
    assert_eq!(st2.considered, full.stats.unwrap().considered);
    let e2 = sess2.stats().eval_calls;

    // identical frontier (bit for bit) and identical CSV report
    assert_eq!(frontier_bits(&resumed), frontier_bits(&full));
    assert_eq!(dse::report::csv(&resumed), dse::report::csv(&full));
    // no point is ever evaluated twice across the kill/resume boundary:
    // the two legs together spend exactly the uninterrupted call count
    assert_eq!(e1 + e2, e_full, "every evaluation happened exactly once");

    // a sweep with different sampling parameters refuses the checkpoint
    let cfg_other = SearchConfig {
        checkpoint: Some(ck.clone()),
        strategy: Strategy::Random,
        seed: 99,
        ..base.clone()
    };
    let err = search_in(&fresh_session(), &space, ELEMENTS, &cfg_other)
        .unwrap_err();
    assert!(err.contains("different sweep"), "{err}");

    // resuming a COMPLETE sweep re-evaluates nothing at all
    let sess3 = fresh_session();
    let again = search_in(&sess3, &space, ELEMENTS, &cfg_resume).unwrap();
    assert_eq!(sess3.stats().eval_calls, 0, "finished sweep: pure reload");
    assert_eq!(frontier_bits(&again), frontier_bits(&full));
    std::fs::remove_file(&ck).ok();
}

#[test]
fn seeded_reports_are_identical_across_runs_and_thread_counts() {
    let space = fixed_space();
    let run = |threads: usize| {
        let cfg = SearchConfig {
            strategy: Strategy::Random,
            budget: Some(10),
            seed: 11,
            batch: 3,
            threads: Some(threads),
            ..SearchConfig::default()
        };
        let ex = search_in(&fresh_session(), &space, ELEMENTS, &cfg).unwrap();
        (dse::report::csv(&ex), dse::report::json(&ex))
    };
    let (csv1, json1) = run(1);
    let (csv1b, json1b) = run(1);
    let (csv4, json4) = run(4);
    assert_eq!(csv1, csv1b, "repeatable");
    assert_eq!(json1, json1b, "repeatable");
    assert_eq!(csv1, csv4, "thread count never changes the report");
    assert_eq!(json1, json4, "thread count never changes the report");
    // sanity: the sweep really sampled something
    let unique: HashSet<&str> = csv1.lines().skip(1).collect();
    assert!(!unique.is_empty());
}
