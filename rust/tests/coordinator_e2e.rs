//! Integration: the coordinator driving real numerics end to end,
//! including multi-CU bookkeeping and fixed-point datapaths.

use hbmflow::cli::build_kernel;
use hbmflow::coordinator::{Driver, HelmholtzWorkload};
use hbmflow::datatype::DataType;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    match Runtime::from_default_dir() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping (run `make artifacts`): {e}");
            None
        }
    }
}

fn spec(dtype: DataType, p: usize, cus: usize) -> olympus::SystemSpec {
    let k = build_kernel("helmholtz", p).unwrap();
    let opts = if dtype.is_fixed() {
        OlympusOpts::fixed_point(dtype)
    } else {
        OlympusOpts::dataflow(7)
    }
    .with_cus(cus);
    olympus::generate(&k, &opts, &Platform::alveo_u280()).unwrap()
}

#[test]
fn e2e_f64_batch_exactness() {
    let Some(mut rt) = runtime() else { return };
    let s = spec(DataType::F64, 7, 1);
    let artifact = Driver::artifact_for(&rt, &s, 7).unwrap();
    let w = HelmholtzWorkload::generate(7, 333, 1); // non-multiple of 32
    let mut d = Driver::new(&mut rt, s, artifact);
    let r = d.run(&w, 32).unwrap();
    assert_eq!(r.elements, 333);
    assert!(r.mse_vs_oracle < 1e-24);
    // padded invocations: ceil(333/32) per plan (single batch covers all)
    assert!(r.invocations >= 11);
    assert_eq!(r.outputs.len(), 333 * 343);
}

#[test]
fn e2e_outputs_nonzero_and_bounded() {
    let Some(mut rt) = runtime() else { return };
    let s = spec(DataType::F64, 11, 1);
    let artifact = Driver::artifact_for(&rt, &s, 11).unwrap();
    let w = HelmholtzWorkload::generate(11, 64, 2);
    let mut d = Driver::new(&mut rt, s, artifact);
    let r = d.run(&w, 8).unwrap();
    let nonzero = r.outputs.iter().filter(|x| x.abs() > 1e-12).count();
    assert!(nonzero > r.outputs.len() / 2);
    // scaled-S workload keeps |v| <= 1
    assert!(r.outputs.iter().all(|x| x.abs() <= 1.0 + 1e-9));
}

#[test]
fn e2e_two_cus_split_work_evenly_across_batches() {
    let Some(mut rt) = runtime() else { return };
    let s = spec(DataType::F64, 7, 2);
    let artifact = Driver::artifact_for(&rt, &s, 7).unwrap();
    let w = HelmholtzWorkload::generate(7, 500, 3);
    let mut d = Driver::new(&mut rt, s, artifact);
    let r = d.run(&w, 16).unwrap();
    assert_eq!(r.per_cu_elements.iter().sum::<u64>(), 500);
    assert!(r.mse_vs_oracle < 1e-24);
}

#[test]
fn e2e_fx32_end_to_end_error_budget() {
    let Some(mut rt) = runtime() else { return };
    let s = spec(DataType::Fx32, 11, 1);
    let artifact = Driver::artifact_for(&rt, &s, 11).unwrap();
    assert!(artifact.contains("fx32"));
    let w = HelmholtzWorkload::generate(11, 64, 4);
    let mut d = Driver::new(&mut rt, s, artifact);
    let r = d.run(&w, 32).unwrap();
    // Q8.24 grid: per-value error bounded by a few quantization steps
    assert!(r.max_abs_err < 1e-5, "max err {}", r.max_abs_err);
    assert!(r.mse_vs_oracle > 0.0);
}

#[test]
fn e2e_deterministic_outputs() {
    let Some(mut rt) = runtime() else { return };
    let w = HelmholtzWorkload::generate(7, 96, 5);
    let run = |rt: &mut Runtime| {
        let s = spec(DataType::F64, 7, 1);
        let artifact = Driver::artifact_for(rt, &s, 7).unwrap();
        Driver::new(rt, s, artifact).run(&w, 0).unwrap().outputs
    };
    let a = run(&mut rt);
    let b = run(&mut rt);
    assert_eq!(a, b);
}
