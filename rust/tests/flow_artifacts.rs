//! Integration: staged-artifact persistence. Every stage artifact for
//! every shipped kernel (the six `examples/kernels/*.cfd` programs plus
//! the three builtins) serializes to versioned JSON and reloads to a
//! value that produces bit-identical downstream results — estimate and
//! simulation — compared to the never-serialized pipeline.

use std::path::PathBuf;

use hbmflow::flow::{Artifact, Evaluated, Flow};
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::OlympusOpts;
use hbmflow::platform::Platform;

fn kernel_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/kernels")
}

/// The three builtins plus every shipped `.cfd` kernel.
fn sources() -> Vec<KernelSource> {
    let mut v: Vec<KernelSource> = ["helmholtz", "interpolation", "gradient"]
        .iter()
        .map(|n| KernelSource::builtin(n))
        .collect();
    let mut files: Vec<PathBuf> = std::fs::read_dir(kernel_dir())
        .expect("examples/kernels exists")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "cfd"))
        .collect();
    files.sort();
    assert!(files.len() >= 6, "kernel library shrank: {files:?}");
    v.extend(files.into_iter().map(KernelSource::file));
    v
}

/// Full evaluated payload as canonical JSON — the bit-identical check
/// (covers the estimate and every simulation number).
fn canon(ev: &Evaluated) -> String {
    Artifact::Evaluated(ev.clone()).to_json().to_string()
}

#[test]
fn every_stage_roundtrips_to_identical_downstream_results() {
    let platform = Platform::alveo_u280();
    for source in sources() {
        let p = if source.parameterized() {
            7
        } else {
            source.nominal_degree()
        };
        let parsed = Flow::from_source(source.clone()).parse(p).unwrap();
        let lowered = parsed.lower().unwrap();
        let opts = {
            let mut o = OlympusOpts::dataflow(7.min(lowered.kernel.nests.len()));
            o.dtype = hbmflow::datatype::DataType::F64;
            o
        };
        let mapped = lowered.map(&opts, &platform).unwrap();
        let direct = canon(&mapped.simulate(100_000));

        let path = std::env::temp_dir().join(format!(
            "hbmflow_artifact_{}_{p}.json",
            source.name()
        ));
        let stages = [
            Artifact::Parsed(parsed.clone()),
            Artifact::Lowered(lowered.clone()),
            Artifact::Mapped(mapped.clone()),
        ];
        for art in stages {
            let stage = art.stage();
            art.save(&path).unwrap();
            let remapped = match Artifact::load(&path).unwrap() {
                Artifact::Parsed(a) => {
                    a.lower().unwrap().map(&opts, &platform).unwrap()
                }
                Artifact::Lowered(a) => a.map(&opts, &platform).unwrap(),
                Artifact::Mapped(a) => a,
                Artifact::Evaluated(_) => unreachable!("not saved here"),
            };
            let resumed = canon(&remapped.simulate(100_000));
            assert_eq!(
                direct,
                resumed,
                "{} stage {stage}: reload must be bit-identical",
                source.name()
            );
        }
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn evaluated_artifacts_reload_and_reverify_their_results() {
    let platform = Platform::alveo_u280();
    for source in sources() {
        let p = if source.parameterized() {
            11
        } else {
            source.nominal_degree()
        };
        let lowered = Flow::from_source(source.clone())
            .parse(p)
            .unwrap()
            .lower()
            .unwrap();
        let opts = OlympusOpts::dataflow(7.min(lowered.kernel.nests.len()));
        let ev = lowered
            .map(&opts, &platform)
            .unwrap()
            .simulate(50_000);
        let path = std::env::temp_dir().join(format!(
            "hbmflow_artifact_ev_{}_{p}.json",
            source.name()
        ));
        Artifact::Evaluated(ev.clone()).save(&path).unwrap();
        // load recomputes the whole chain and cross-checks the recorded
        // hls + sim sections — success IS the bit-identical assertion
        let back = Artifact::load(&path).unwrap();
        let Artifact::Evaluated(b) = back else {
            panic!("stage changed on reload");
        };
        assert_eq!(canon(&ev), canon(&b), "{}", source.name());
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn artifacts_embed_the_source_so_the_file_can_vanish() {
    // write a program, save its artifact, delete the program: the
    // artifact still reloads and evaluates
    let dir = std::env::temp_dir();
    let cfd = dir.join("hbmflow_vanishing.cfd");
    std::fs::write(
        &cfd,
        "var input a : [5]\nvar input b : [5]\nvar output c : [5]\nc = a * b\n",
    )
    .unwrap();
    let lowered = Flow::from_source(KernelSource::file(&cfd))
        .parse(0)
        .unwrap()
        .lower()
        .unwrap();
    let art = dir.join("hbmflow_vanishing.flow.json");
    Artifact::Lowered(lowered).save(&art).unwrap();
    std::fs::remove_file(&cfd).unwrap();

    let back = Artifact::load(&art).unwrap();
    let Artifact::Lowered(l) = back else {
        panic!("stage changed");
    };
    let ev = l
        .map(&OlympusOpts::baseline(), &Platform::alveo_u280())
        .unwrap()
        .simulate(10_000);
    assert!(ev.sim().unwrap().gflops_system > 0.0);
    std::fs::remove_file(&art).ok();
}

#[test]
fn mapped_artifacts_pin_the_vitis_package() {
    let platform = Platform::alveo_u280();
    let lowered = Flow::from_source(KernelSource::builtin("helmholtz"))
        .parse(7)
        .unwrap()
        .lower()
        .unwrap();
    let opts = OlympusOpts::dataflow(7.min(lowered.kernel.nests.len()));
    let mapped = lowered.map(&opts, &platform).unwrap();
    let direct = mapped.vitis_package();

    let json = Artifact::Mapped(mapped.clone()).to_json().to_string();
    assert!(json.contains("\"vitis\""), "mapped artifacts carry a vitis section");
    assert!(json.contains(&direct.fingerprint()), "fingerprint recorded: {json}");

    let path = std::env::temp_dir().join("hbmflow_artifact_vitis.json");
    Artifact::Mapped(mapped).save(&path).unwrap();
    let Artifact::Mapped(back) = Artifact::load(&path).unwrap() else {
        panic!("stage changed on reload");
    };
    // the reloaded artifact re-emits the package byte-for-byte
    assert_eq!(direct.bundle(), back.vitis_package().bundle());

    // a tampered fingerprint is an incompatible build, not silent drift
    let text = std::fs::read_to_string(&path).unwrap();
    let tampered = text.replace(&direct.fingerprint(), "0000000000000000");
    assert_ne!(text, tampered, "fingerprint appears in the document");
    std::fs::write(&path, tampered).unwrap();
    let err = Artifact::load(&path).unwrap_err().to_string();
    assert!(err.contains("incompatible build"), "{err}");
    std::fs::remove_file(&path).ok();
}
