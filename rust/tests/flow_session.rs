//! Integration: the flow Session's batch evaluation service and shared
//! artifact cache — batch results are pinned to sequential per-config
//! evaluation, and a full dse sweep performs exactly one parse + one
//! lower per distinct (kernel, degree) regardless of how many dtypes,
//! option sets, and CU counts multiply the space.

use hbmflow::dse::{self, SearchSpace};
use hbmflow::flow::{EvalKind, FlowRequest, Session};
use hbmflow::olympus::BusMode;
use hbmflow::platform::Platform;

/// A moderate multi-axis space: 2 degrees × 4 dtypes × 2 CU counts ×
/// 3 dataflow settings × sharing on/off (structurally pruned).
fn space() -> SearchSpace {
    let mut s = SearchSpace::default_for("helmholtz");
    s.cu_counts = vec![1, 2];
    s.dataflow = vec![None, Some(1), Some(7)];
    s.double_buffering = vec![true];
    s.bus_modes = vec![BusMode::Wide256Parallel];
    s.fifo_depths = vec![None];
    s
}

#[test]
fn evaluate_batch_matches_sequential_evaluation_bit_for_bit() {
    let sp = space();
    let points = sp.enumerate();
    assert!(points.len() >= 30, "space too small: {}", points.len());
    let reqs: Vec<FlowRequest> = points
        .iter()
        .map(|pt| FlowRequest {
            source: sp.source.clone(),
            p: pt.p,
            opts: pt.opts.clone(),
            eval: EvalKind::Simulate { elements: 200_000 },
        })
        .collect();

    let batch_session = Session::new(Platform::alveo_u280());
    let batch = batch_session.evaluate_batch_with(&reqs, Some(4));

    let seq_session = Session::new(Platform::alveo_u280());
    let sequential: Vec<_> = reqs.iter().map(|r| seq_session.evaluate(r)).collect();

    assert_eq!(batch.len(), sequential.len());
    for (a, b) in batch.iter().zip(&sequential) {
        match (&a.result, &b.result) {
            (Ok(x), Ok(y)) => {
                assert_eq!(x.hls.total, y.hls.total);
                assert_eq!(x.hls.fmax_mhz.to_bits(), y.hls.fmax_mhz.to_bits());
                let (sx, sy) = (x.sim().unwrap(), y.sim().unwrap());
                assert_eq!(sx.gflops_system.to_bits(), sy.gflops_system.to_bits());
                assert_eq!(sx.gflops_cu.to_bits(), sy.gflops_cu.to_bits());
                assert_eq!(sx.energy_j.to_bits(), sy.energy_j.to_bits());
                assert_eq!(sx.conflict_stalls, sy.conflict_stalls);
                assert_eq!(sx.switch_crossings, sy.switch_crossings);
            }
            (Err(x), Err(y)) => assert_eq!(x, y),
            _ => panic!("batch and sequential disagree on feasibility"),
        }
    }

    // both sessions parsed + lowered exactly once per degree (7 and 11)
    for s in [&batch_session, &seq_session] {
        let st = s.stats();
        assert_eq!(st.parsed_misses, 2, "{st:?}");
        assert_eq!(st.lowered_misses, 2, "{st:?}");
        assert_eq!(st.lowered_hits as usize, reqs.len() - 2, "{st:?}");
    }
}

#[test]
fn dse_sweep_parses_and_lowers_once_per_degree() {
    let session = Session::new(Platform::alveo_u280());
    let ex = dse::explore_in(&session, &space(), 200_000, Some(4)).unwrap();
    assert!(ex.enumerated() >= 30);
    assert!(ex.feasible_count() > 0);

    let st = session.stats();
    assert_eq!(st.parsed_misses, 2, "one parse per (kernel, p): {st:?}");
    assert_eq!(st.lowered_misses, 2, "one lower per (kernel, p): {st:?}");
    // every candidate evaluation hit the lowered cache instead of
    // rebuilding the kernel (the adaptive sweep's screening pass covers
    // all candidates; its exact pass re-requests only the survivors,
    // each a further cache hit)
    assert!(
        st.lowered_hits as usize >= ex.enumerated() - 2,
        "candidates served from cache: {st:?}"
    );
    // the exact pass reuses the screening pass's Mapped artifacts:
    // misses only in the screen (a rare generation race may double-count
    // a key, hence >=), and every survivor re-request is a hit
    assert!(st.mapped_misses as usize >= ex.enumerated(), "{st:?}");
    assert!(st.mapped_hits >= 1, "survivors re-served from cache: {st:?}");
}

#[test]
fn explore_in_equals_explore_with_a_fresh_session() {
    let sp = space();
    let session = Session::new(Platform::alveo_u280());
    let a = dse::explore_in(&session, &sp, 200_000, Some(2)).unwrap();
    let b = dse::explore(&sp, &Platform::alveo_u280(), 200_000, Some(2)).unwrap();
    assert_eq!(a.enumerated(), b.enumerated());
    assert_eq!(a.frontier, b.frontier);
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.point.label(), y.point.label());
        match (&x.result, &y.result) {
            (Ok(ex), Ok(ey)) => assert_eq!(
                ex.sim.gflops_system.to_bits(),
                ey.sim.gflops_system.to_bits()
            ),
            (Err(ex), Err(ey)) => assert_eq!(ex, ey),
            _ => panic!("sessions disagree on {}", x.point.label()),
        }
    }
}
