//! Golden-file pin of the Vitis emission back-end: every shipped
//! kernel — the three dense builtins plus the seven
//! `examples/kernels/*.cfd` programs (including the indexed
//! `gather_interp`) — at two pinned system points, five files each,
//! byte-compared against `tests/golden/vitis/`.
//!
//! Bless workflow: a missing golden file is written on first run (so
//! the suite bootstraps itself on a fresh checkout); `HBMFLOW_BLESS=1`
//! rewrites all of them after an intentional emitter change. CI reruns
//! the bless pass and fails on `git diff` drift.

use std::path::{Path, PathBuf};

use hbmflow::datatype::DataType;
use hbmflow::flow::Flow;
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::{ChannelPolicy, OlympusOpts};
use hbmflow::platform::Platform;

fn kernel_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/kernels")
}

fn golden_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/vitis")
}

/// The three builtins plus every shipped `.cfd` kernel (the same
/// closure `flow_artifacts` walks).
fn sources() -> Vec<KernelSource> {
    let mut v: Vec<KernelSource> = ["helmholtz", "interpolation", "gradient"]
        .iter()
        .map(|n| KernelSource::builtin(n))
        .collect();
    let mut files: Vec<PathBuf> = std::fs::read_dir(kernel_dir())
        .expect("examples/kernels exists")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "cfd"))
        .collect();
    files.sort();
    assert!(files.len() >= 6, "kernel library shrank: {files:?}");
    v.extend(files.into_iter().map(KernelSource::file));
    v
}

/// The two pinned system points per kernel: the single-CU f64
/// dataflow design and a 2-CU fixed-point striped variant, dataflow
/// clamped to the kernel's nest count like the CLI does.
fn points(nests: usize) -> Vec<(&'static str, OlympusOpts)> {
    let mut local = OlympusOpts::dataflow(7.min(nests));
    local.dtype = DataType::F64;
    let mut striped = OlympusOpts::fixed_point(DataType::Fx32)
        .with_cus(2)
        .with_policy(ChannelPolicy::Striped);
    striped.dataflow = striped.dataflow.map(|g| g.min(nests));
    vec![("cu1_f64_local", local), ("cu2_fx32_striped", striped)]
}

/// Byte-compare one emitted file against its golden twin; bless on
/// request or when the golden file does not exist yet.
fn check(golden: &Path, text: &str, blessed: &mut usize) {
    let bless = std::env::var_os("HBMFLOW_BLESS").is_some();
    if bless || !golden.exists() {
        std::fs::create_dir_all(golden.parent().unwrap()).unwrap();
        std::fs::write(golden, text).unwrap();
        *blessed += 1;
        return;
    }
    let want = std::fs::read_to_string(golden).unwrap();
    assert_eq!(
        want,
        text,
        "golden drift at {} — rerun with HBMFLOW_BLESS=1 to re-pin",
        golden.display()
    );
}

#[test]
fn vitis_packages_match_the_golden_tree() {
    let platform = Platform::alveo_u280();
    let root = golden_root();
    let mut blessed = 0usize;
    let mut checked = 0usize;
    for source in sources() {
        let p = if source.parameterized() {
            7
        } else {
            source.nominal_degree()
        };
        let lowered = Flow::from_source(source.clone())
            .parse(p)
            .unwrap()
            .lower()
            .unwrap();
        for (point, opts) in points(lowered.kernel.nests.len()) {
            let mapped = lowered.map(&opts, &platform).unwrap();
            let pkg = mapped.vitis_package();
            assert_eq!(pkg.files().len(), 5, "{} {point}", source.name());
            for (path, text) in pkg.files() {
                let golden = root.join(source.name()).join(point).join(path);
                check(&golden, text, &mut blessed);
                checked += 1;
            }
        }
    }
    // 10 kernels x 2 points x 5 files — the full pinned closure
    assert_eq!(checked, 10 * 2 * 5, "golden closure shrank");
    if blessed > 0 {
        eprintln!("blessed {blessed}/{checked} golden files under {}", root.display());
    }
}
