//! Integration: the U280 interconnect model end to end (DESIGN.md
//! §"Memory interconnect model") — switch-crossing latency ordering,
//! per-channel turnaround appearing only on shared-direction layouts,
//! the ≥8-CU shared-channel throughput regression (paper Fig. 17
//! direction), and the DSE frontier rejecting crossing-heavy
//! allocations mechanistically.

use hbmflow::cli::build_kernel;
use hbmflow::datatype::DataType;
use hbmflow::dse::{self, SearchSpace};
use hbmflow::hbm::Interconnect;
use hbmflow::hls;
use hbmflow::olympus::{self, BusMode, ChannelPolicy, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::sim::{self, SimResult};

fn run(opts: OlympusOpts, p: usize, n: u64) -> SimResult {
    let platform = Platform::alveo_u280();
    let k = build_kernel("helmholtz", p).unwrap();
    let spec = olympus::generate(&k, &opts, &platform).unwrap();
    let est = hls::estimate(&spec, &platform);
    sim::simulate(&spec, &est, &platform, n)
}

const N: u64 = 500_000;

#[test]
fn same_segment_beats_cross_segment_latency_and_rate() {
    let ic = Interconnect::hbm(&Platform::alveo_u280().hbm);
    // latency: strictly ordered in switch distance
    assert!(ic.round_trip_cycles(0) < ic.round_trip_cycles(1));
    assert!(ic.round_trip_cycles(1) < ic.round_trip_cycles(3));
    assert!(ic.round_trip_cycles(3) < ic.round_trip_cycles(7));
    // sustainable rate: local is full, crossings throttle monotonically
    assert_eq!(ic.effective_rate(0), 1.0);
    assert!(ic.effective_rate(1) < ic.effective_rate(0));
    assert!(ic.effective_rate(3) < ic.effective_rate(1));
    assert!(ic.effective_rate(7) < ic.effective_rate(3));
}

#[test]
fn turnaround_only_when_directions_share_a_channel() {
    // <8 CUs: Olympus separates read and write channels — the read
    // stage is exactly the input word count, no controller turnaround.
    let separated = run(OlympusOpts::dataflow(7).with_cus(4), 11, N);
    let in_words = (121 + 2 * 1331) as u64;
    assert_eq!(separated.stage_intervals[0].1, in_words);

    // ≥8 CUs: ping/pong channels carry both directions — the read stage
    // pays tWTR+tRTW and waits out the overlapped write stream.
    let shared = run(OlympusOpts::dataflow(7).with_cus(8), 11, N);
    let t = Platform::alveo_u280().hbm.switch;
    assert_eq!(
        shared.stage_intervals[0].1,
        in_words + 1331 + t.t_wtr_cycles + t.t_rtw_cycles
    );
}

#[test]
fn shared_channel_layout_loses_per_cu_throughput() {
    // Paper Fig. 17 direction: past 8 CUs the shared-channel layout
    // erodes per-CU throughput, so doubling CUs from 4 to 8 must yield
    // strictly less than 2x aggregate kernel throughput (in cycles, so
    // the comparison is frequency-independent).
    let platform = Platform::alveo_u280();
    let k = build_kernel("helmholtz", 11).unwrap();
    let interval = |cus: usize| {
        let opts = OlympusOpts::dataflow(7).with_cus(cus);
        let spec = olympus::generate(&k, &opts, &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        sim::stages(&spec, &est).max_interval() as f64
    };
    let separated = interval(4);
    let shared = interval(8);
    assert!(shared > separated, "shared channels slow the pipeline");
    let agg4 = 4.0 / separated; // elements per cycle, aggregate
    let agg8 = 8.0 / shared;
    assert!(
        agg8 < 2.0 * agg4,
        "8-CU aggregate {agg8} must fall short of 2x the 4-CU {agg4}"
    );
    assert!(agg8 > agg4, "replication still gains in kernel cycles");
}

#[test]
fn striped_allocation_pays_for_its_crossings() {
    let local = run(OlympusOpts::dataflow(7), 11, N);
    let striped = run(
        OlympusOpts::dataflow(7).with_policy(ChannelPolicy::Striped),
        11,
        N,
    );
    assert_eq!(local.switch_crossings, 0);
    assert!(striped.switch_crossings > 0);
    assert!(striped.hbm_fill_cycles > local.hbm_fill_cycles);
    assert!(
        striped.gflops_system < local.gflops_system,
        "crossing throttle must cost throughput: striped {} vs local {}",
        striped.gflops_system,
        local.gflops_system
    );
}

#[test]
fn channel_utilization_is_reported_per_allocated_channel() {
    let r = run(OlympusOpts::dataflow(7).with_cus(2), 11, N);
    assert_eq!(r.channel_utilization.len(), 8, "2 CUs x 4 PCs");
    for &(pc, u) in &r.channel_utilization {
        assert!(pc < 8, "local-first keeps the first eight channels");
        assert!(u > 0.0 && u <= 1.0, "HBM[{pc}] utilization {u}");
    }
    assert!(r.max_channel_utilization <= 1.0);
}

#[test]
fn dse_frontier_rejects_the_striped_twin() {
    let mut s = SearchSpace::default_for("helmholtz");
    s.degrees = vec![11];
    s.dtypes = vec![DataType::Fx32];
    s.cu_counts = vec![1];
    s.dataflow = vec![Some(7)];
    s.double_buffering = vec![true];
    s.bus_modes = vec![BusMode::Wide256Parallel];
    s.mem_sharing = vec![false];
    s.fifo_depths = vec![None];
    s.channel_policies = vec![ChannelPolicy::LocalFirst, ChannelPolicy::Striped];
    let ex = dse::explore(&s, &Platform::alveo_u280(), 200_000, Some(2)).unwrap();
    assert_eq!(ex.enumerated(), 2, "one local-first twin, one striped");

    let policy_of = |i: usize| ex.outcomes[i].point.opts.channel_policy.clone();
    let g = |i: usize| ex.outcomes[i].result.as_ref().unwrap().sim.gflops_system;
    let local = (0..2).find(|&i| policy_of(i) == ChannelPolicy::LocalFirst).unwrap();
    let striped = 1 - local;
    assert!(g(local) > g(striped));
    assert!(
        ex.is_on_frontier(local),
        "the all-local allocation survives"
    );
    assert!(
        !ex.is_on_frontier(striped),
        "the crossing-heavy allocation is dominated, not fitted away"
    );
}
