//! Property tests for the Vitis emission back-end: cross-file
//! consistency — C++ port names, `link.cfg` `sp=` lines, host
//! `XCL_MEM_TOPOLOGY` flags, and the routed channel map must all agree
//! — plus byte-determinism, for every shipped kernel at two system
//! points.

use std::collections::BTreeSet;
use std::path::PathBuf;

use hbmflow::codegen::vitis;
use hbmflow::datatype::DataType;
use hbmflow::flow::{Flow, Mapped};
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::{ChannelPolicy, MemoryKind, OlympusOpts};
use hbmflow::platform::Platform;

fn kernel_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/kernels")
}

/// The three builtins plus every shipped `.cfd` kernel.
fn sources() -> Vec<KernelSource> {
    let mut v: Vec<KernelSource> = ["helmholtz", "interpolation", "gradient"]
        .iter()
        .map(|n| KernelSource::builtin(n))
        .collect();
    let mut files: Vec<PathBuf> = std::fs::read_dir(kernel_dir())
        .expect("examples/kernels exists")
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "cfd"))
        .collect();
    files.sort();
    assert!(files.len() >= 6, "kernel library shrank: {files:?}");
    v.extend(files.into_iter().map(KernelSource::file));
    v
}

/// The same two system points the golden suite pins.
fn points(nests: usize) -> Vec<OlympusOpts> {
    let mut local = OlympusOpts::dataflow(7.min(nests));
    local.dtype = DataType::F64;
    let mut striped = OlympusOpts::fixed_point(DataType::Fx32)
        .with_cus(2)
        .with_policy(ChannelPolicy::Striped);
    striped.dataflow = striped.dataflow.map(|g| g.min(nests));
    vec![local, striped]
}

/// Every shipped kernel mapped at both points (18 systems).
fn mapped_points() -> Vec<Mapped> {
    let platform = Platform::alveo_u280();
    let mut v = Vec::new();
    for source in sources() {
        let p = if source.parameterized() {
            7
        } else {
            source.nominal_degree()
        };
        let lowered = Flow::from_source(source.clone())
            .parse(p)
            .unwrap()
            .lower()
            .unwrap();
        for opts in points(lowered.kernel.nests.len()) {
            v.push(lowered.map(&opts, &platform).unwrap());
        }
    }
    assert_eq!(v.len(), 18, "system-point closure shrank");
    v
}

#[test]
fn vitis_sp_ports_exist_in_the_cpp_and_channels_in_the_routed_map() {
    for m in mapped_points() {
        let pkg = m.vitis_package();
        let cfg = vitis::parse_connectivity(pkg.file("link.cfg").unwrap()).unwrap();
        let cpp = pkg.file(&format!("src/{}.cpp", m.spec.kernel.name)).unwrap();
        assert_eq!(cfg.kernel, m.spec.kernel.name);
        assert_eq!(cfg.instances.len(), m.spec.num_cus, "{}", m.spec.name);
        let want: usize = m.spec.channels.iter().map(|c| c.read.len() + c.write.len()).sum();
        assert_eq!(cfg.sp.len(), want, "{}", m.spec.name);
        let tag = match m.spec.opts.memory {
            MemoryKind::Hbm => "HBM",
            MemoryKind::Ddr4 => "DDR",
        };
        let mut pcs = BTreeSet::new();
        for cu in &m.spec.hbm_map.cus {
            for r in cu.read.iter().chain(cu.write.iter()) {
                pcs.insert(r.channel);
            }
        }
        for b in &cfg.sp {
            assert!(cpp.contains(&format!("port={}", b.port)), "{}: {}", m.spec.name, b.port);
            assert_eq!(b.memory, tag, "{}", m.spec.name);
            assert!(pcs.contains(&b.channel), "{} pc {}", m.spec.name, b.channel);
        }
    }
}

#[test]
fn vitis_host_topology_agrees_with_the_link_cfg_one_to_one() {
    for m in mapped_points() {
        let pkg = m.vitis_package();
        let cfg = vitis::parse_connectivity(pkg.file("link.cfg").unwrap()).unwrap();
        let host = vitis::parse_host_topology(pkg.file("src/host.cpp").unwrap()).unwrap();
        assert_eq!(host, cfg.sp, "{}: host flags must mirror the cfg", m.spec.name);
    }
}

#[test]
fn vitis_cfg_parses_back_to_the_channel_assignment() {
    for m in mapped_points() {
        let pkg = m.vitis_package();
        let cfg = vitis::parse_connectivity(pkg.file("link.cfg").unwrap()).unwrap();
        let chans = vitis::cfg_channel_assignment(&cfg).unwrap();
        assert_eq!(chans, m.spec.channels, "{}", m.spec.name);
        // and the flat assignment is exactly the routed map's projection
        for (cu, routes) in chans.iter().zip(m.spec.hbm_map.cus.iter()) {
            let r: Vec<u32> = routes.read.iter().map(|x| x.channel).collect();
            let w: Vec<u32> = routes.write.iter().map(|x| x.channel).collect();
            assert_eq!(cu.read, r, "{}", m.spec.name);
            assert_eq!(cu.write, w, "{}", m.spec.name);
        }
    }
}

/// One full bundle built from scratch (parse → lower → map → emit).
fn bundle_for(point: usize) -> String {
    let platform = Platform::alveo_u280();
    let lowered = Flow::from_source(KernelSource::builtin("helmholtz"))
        .parse(7)
        .unwrap()
        .lower()
        .unwrap();
    let opts = points(lowered.kernel.nests.len()).swap_remove(point);
    lowered.map(&opts, &platform).unwrap().vitis_package().bundle()
}

#[test]
fn vitis_emission_is_byte_deterministic_across_runs_and_threads() {
    for point in 0..2 {
        let first = bundle_for(point);
        assert_eq!(first, bundle_for(point), "re-run drifted");
        let handles: Vec<_> = (0..4)
            .map(|_| std::thread::spawn(move || bundle_for(point)))
            .collect();
        for h in handles {
            assert_eq!(first, h.join().unwrap(), "thread drifted");
        }
    }
}
