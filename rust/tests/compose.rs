//! Integration: multi-kernel composition on one device (DESIGN.md
//! §2.10), over the paper's interpolation → gradient → helmholtz
//! pipeline. Pins the invariants the subsystem promises:
//!
//!  * the 32 pseudo-channels partition disjointly across members;
//!  * the pooled resource budget is checked at generation time;
//!  * routing intermediates through on-chip FIFOs beats the
//!    time-multiplexed (reconfigure + host round-trip) schedule;
//!  * the composed analytic bounds bracket the composed event timeline;
//!  * link FIFOs are sized by mnemosyne from the adjacent port widths.

use std::collections::HashSet;

use hbmflow::flow::{self, Flow, Lowered};
use hbmflow::kernels::KernelSource;
use hbmflow::mnemosyne;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::sim;

const TRIO: [&str; 3] = ["interpolation", "gradient", "helmholtz"];

fn lowered(name: &str, p: usize) -> Lowered {
    Flow::from_source(KernelSource::builtin(name))
        .parse(p)
        .unwrap()
        .lower()
        .unwrap()
}

fn trio(p: usize) -> Vec<Lowered> {
    TRIO.iter().map(|k| lowered(k, p)).collect()
}

fn compose_trio(opts: &OlympusOpts) -> flow::Composed {
    flow::compose(&trio(7), opts, &Platform::alveo_u280()).unwrap()
}

#[test]
fn members_get_disjoint_slices_of_the_channel_partition() {
    let c = compose_trio(&OlympusOpts::baseline());
    let sys = &c.system;
    assert_eq!(sys.stages.len(), 3);
    let mut seen = HashSet::new();
    for s in &sys.stages {
        for cu in &s.channels {
            for &pc in cu.read.iter().chain(&cu.write) {
                // a pseudo-channel may serve several ports of ONE stage
                // (shared read/write) but never two different stages
                seen.insert(pc);
            }
        }
    }
    let per_stage: usize = sys.stages.iter().map(|s| s.total_pcs()).sum();
    assert_eq!(seen.len(), per_stage, "stages share a pseudo-channel");
    assert_eq!(sys.total_pcs(), per_stage);
    assert!(sys.total_pcs() <= 32);
    // and the composed validate agrees
    sys.validate(&Platform::alveo_u280()).unwrap();
}

#[test]
fn channel_over_demand_fails_at_generation_not_at_runtime() {
    // 3 members x 16 CUs x 1 PC = 48 > 32 pseudo-channels
    let err = flow::compose(
        &trio(7),
        &OlympusOpts::baseline().with_cus(16),
        &Platform::alveo_u280(),
    )
    .unwrap_err();
    assert_eq!(err.stage, flow::FlowStage::Map);
    assert!(
        err.message.contains("composed channel allocation"),
        "{err}"
    );
}

#[test]
fn resource_budget_is_checked_for_the_whole_composition() {
    let platform = Platform::alveo_u280();
    // the published trio at 1 CU each fits, and the pooled estimate the
    // feasibility check used is recorded on the system
    let ok = compose_trio(&OlympusOpts::baseline());
    assert!(ok
        .system
        .resources
        .fits_in(&platform.total_resources()));
    assert!(ok.system.resources.lut > 0 && ok.system.resources.bram > 0);

    // 10 CUs per member stays channel-feasible (30 of 32 PCs) but piles
    // 30 double-precision CUs onto one device: compose must either
    // reject with the budget named, or — if the estimator says this
    // fits — hand back a system whose pooled total provably fits. A
    // third outcome (accepted but over budget) is the bug this pins.
    let opts = OlympusOpts::baseline().with_cus(10);
    let members = trio(11);
    for l in &members {
        // generation alone imposes no area check, so each member builds
        olympus::generate(&l.kernel, &opts, &platform).unwrap();
    }
    match flow::compose(&members, &opts, &platform) {
        Err(e) => {
            assert_eq!(e.stage, flow::FlowStage::Map);
            assert!(e.message.contains("exceeds the device"), "{e}");
        }
        Ok(c) => {
            assert!(c
                .system
                .resources
                .fits_in(&platform.total_resources()));
        }
    }
}

#[test]
fn fifo_routing_beats_the_time_multiplexed_schedule() {
    // the acceptance criterion: on-chip intermediates + overlapped
    // stages vs reconfigure-and-round-trip
    let c = compose_trio(&OlympusOpts::baseline());
    let r = c.simulate(200_000);
    assert!(r.total_s > 0.0);
    assert!(
        r.total_s < r.time_multiplexed_s,
        "fifo-routed {} s should beat time-multiplexed {} s",
        r.total_s,
        r.time_multiplexed_s
    );
    assert!(r.speedup_vs_time_multiplexed > 1.0);
}

#[test]
fn composed_bounds_bracket_the_composed_event_timeline() {
    for elements in [0u64, 1, 1_000, 250_000] {
        let c = compose_trio(&OlympusOpts::baseline());
        let r = c.simulate(elements);
        assert!(
            r.analytic.brackets(r.total_s),
            "{elements} elements: [{}, {}] misses {}",
            r.analytic.lower_s,
            r.analytic.upper_s,
            r.total_s
        );
    }
}

#[test]
fn stages_agree_on_one_lane_aligned_batch() {
    let c = compose_trio(&OlympusOpts::bus_parallel());
    let sys = &c.system;
    assert!(sys.batch_elements > 0);
    for s in &sys.stages {
        assert_eq!(s.batch_elements, sys.batch_elements);
        assert_eq!(sys.batch_elements % s.lanes, 0);
    }
}

#[test]
fn link_fifos_come_from_mnemosyne_and_cover_the_wider_port() {
    let c = compose_trio(&OlympusOpts::baseline());
    let sys = &c.system;
    assert_eq!(sys.links.len(), sys.stages.len() - 1);
    for l in &sys.links {
        assert_eq!(l.consumer, l.producer + 1);
        let prod = &sys.stages[l.producer];
        let cons = &sys.stages[l.consumer];
        let expect = mnemosyne::link_fifo(
            prod.kernel.output_words(),
            cons.kernel.input_words(),
            l.fifo.word_bytes,
            c.opts.fifo_depth,
        );
        assert_eq!(l.fifo, expect);
        assert!(l.fifo.depth_words > 0);
        assert!(l.fifo.bram_halves() >= 1);
    }
}

#[test]
fn composed_sim_reports_a_stage_per_member() {
    let c = compose_trio(&OlympusOpts::baseline());
    let r = c.simulate(50_000);
    assert_eq!(r.stage_names, TRIO.to_vec());
    assert_eq!(r.stage_t_batch_s.len(), 3);
    assert!(r.stage_t_batch_s.iter().all(|&t| t > 0.0));
    assert!(r.pcie_in_s > 0.0 && r.pcie_out_s > 0.0);
    assert!(r.freq_mhz > 0.0);
    assert!(r.gflops_system > 0.0);
    // the composed bottleneck is one of the named resources
    let mut valid: Vec<String> =
        TRIO.iter().map(|s| s.to_string()).collect();
    valid.push("pcie-in".into());
    valid.push("pcie-out".into());
    assert!(valid.contains(&r.bottleneck), "{}", r.bottleneck);
}

#[test]
fn layout_axis_ranks_fused_on_the_frontier() {
    let members = trio(7);
    let opts = OlympusOpts::baseline();
    let pairs: Vec<(&hbmflow::ir::affine::Kernel, OlympusOpts)> = members
        .iter()
        .map(|l| (&l.kernel, opts.clone()))
        .collect();
    let ex = hbmflow::dse::explore_layouts(&pairs, &Platform::alveo_u280(), 50_000);
    assert_eq!(ex.layouts.len(), 4, "2^(K-1) layouts for K=3");
    assert!(!ex.frontier.is_empty());
    // fusing everything skips every host round trip and overlaps all
    // three stages: it must beat the fully time-multiplexed layout,
    // which means the fastest layout fuses at least one edge
    let fully = ex.layouts[0b11].total_s.expect("trio fuses at 1 CU each");
    let split = ex.layouts[0b00].total_s.expect("singletons are feasible");
    assert!(fully < split, "fused {fully} vs split {split}");
    assert_ne!(ex.fastest().unwrap().fuse_mask, 0);
}

#[test]
fn composed_timeline_reduces_to_the_chain_for_one_batch() {
    let cfg = sim::compose::ComposedTimelineConfig {
        n_batches: 1,
        t_in: 0.25,
        t_out: 0.5,
        stages: vec![
            sim::compose::ComposedStage {
                t_batch: 1.0,
                n_cus: 2,
                credit: 3,
            },
            sim::compose::ComposedStage {
                t_batch: 2.0,
                n_cus: 1,
                credit: 1,
            },
        ],
    };
    let t = sim::compose::run_composed_timeline(&cfg);
    assert!((t - 3.75).abs() < 1e-12, "{t}");
    assert!(sim::compose::composed_bounds(&cfg).brackets(t));
}
