//! Fig. 18: power and energy efficiency of the Dataflow-7 variants
//! (dtype x p x CU count).

use hbmflow::cli::build_kernel;
use hbmflow::datatype::DataType;
use hbmflow::hls;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::power::INTEL_XEON_AVG_W;
use hbmflow::platform::Platform;
use hbmflow::report::{self, paper};
use hbmflow::sim;
use hbmflow::util::bench::section;

fn main() {
    section("Fig. 18 — power and efficiency (Dataflow-7)");
    let platform = Platform::alveo_u280();
    let n = paper::N_ELEMENTS;

    let mut rows = Vec::new();
    let mut eff = std::collections::HashMap::new();
    for p in [11usize, 7] {
        let kernel = build_kernel("helmholtz", p).unwrap();
        for dtype in [DataType::F64, DataType::Fx64, DataType::Fx32] {
            for cus in [1usize, 2] {
                let mut opts = if dtype.is_fixed() {
                    OlympusOpts::fixed_point(dtype)
                } else {
                    OlympusOpts::dataflow(7)
                };
                opts = opts.with_cus(cus);
                let Ok(spec) = olympus::generate(&kernel, &opts, &platform) else {
                    continue;
                };
                let est = hls::estimate(&spec, &platform);
                if !est.total.fits_in(&platform.total_resources()) {
                    continue;
                }
                let r = sim::simulate(&spec, &est, &platform, n);
                eff.insert((dtype.name(), p, cus), r.efficiency_gflops_w);
                rows.push(vec![
                    format!("{} p={p} x{cus}", dtype.display()),
                    report::f(r.avg_power_w),
                    format!("{:.2}", r.efficiency_gflops_w),
                    report::f(r.gflops_system),
                ]);
            }
        }
    }
    println!(
        "{}",
        report::table(&["configuration", "avg W", "GFLOPS/W", "System"], &rows)
    );

    // Fig. 18 shape: fixed > float; 32 > 64 bit; multi-CU less efficient;
    // fx32 p=11 1 CU is the headline (~4 GOPS/W, ~24.5x Intel).
    let e = |d: &str, p: usize, c: usize| eff[&(d, p, c)];
    assert!(e("fx64", 11, 1) > e("f64", 11, 1), "fixed beats float");
    assert!(e("fx32", 11, 1) > e("fx64", 11, 1), "32 beats 64 bit");
    assert!(e("fx32", 11, 2) < e("fx32", 11, 1), "replication hurts efficiency");
    let best = e("fx32", 11, 1);
    assert!((2.0..7.0).contains(&best), "headline ~4 GOPS/W, got {best}");

    let intel_eff = paper::intel_optimized_gflops("helmholtz") / INTEL_XEON_AVG_W;
    let ratio = best / intel_eff;
    println!(
        "headline: fx32 p=11 1 CU = {best:.2} GOPS/W (paper ~{}), {ratio:.1}x the \
         Intel-optimized estimate (paper {}x)\n",
        paper::FIG18_BEST_GOPS_PER_W,
        paper::FIG18_INTEL_RATIO
    );
    assert!((10.0..45.0).contains(&ratio), "Intel ratio {ratio}");
    println!("shape checks passed: fixed>float, 32>64, 1CU>2CU, ~24x Intel\n");
}
