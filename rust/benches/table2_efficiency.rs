//! Table 2: efficiency of floating-point operators — #Ops, f, ideal vs
//! achieved GFLOPS, efficiency ratio.

use hbmflow::cli::build_kernel;
use hbmflow::hls;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::report::{self, paper};
use hbmflow::sim;
use hbmflow::util::bench::section;

fn main() {
    section("Table 2 — efficiency of floating-point operators (p=11, 1 CU)");
    let kernel = build_kernel("helmholtz", 11).unwrap();
    let platform = Platform::alveo_u280();
    let n = paper::N_ELEMENTS;

    let ladder: Vec<OlympusOpts> = vec![
        OlympusOpts::baseline(),
        OlympusOpts::double_buffering(),
        OlympusOpts::bus_serial(),
        OlympusOpts::bus_parallel(),
        OlympusOpts::dataflow(1),
        OlympusOpts::dataflow(2),
        OlympusOpts::dataflow(3),
        OlympusOpts::dataflow(7),
    ];

    let mut rows = Vec::new();
    for (i, opts) in ladder.iter().enumerate() {
        let spec = olympus::generate(&kernel, opts, &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        let r = sim::simulate(&spec, &est, &platform, n);
        let p = paper::TABLE2[i];
        assert_eq!(
            est.ops(),
            p.ops,
            "{}: operator allocation must match Table 2 exactly",
            opts.label()
        );
        rows.push(vec![
            opts.label(),
            format!("{} (paper {})", est.ops(), p.ops),
            report::f(est.fmax_mhz),
            report::f(est.ideal_gflops()),
            report::f(r.gflops_cu),
            format!("{:.3}", r.efficiency_vs_ideal),
            format!("{:.3}", p.efficiency),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["implementation", "#Ops", "f(MHz)", "ideal", "achieved", "eff", "eff(paper)"],
            &rows
        )
    );

    // Table 2's qualitative claim: the non-pipelined-multiplier designs
    // sit near 0.5 efficiency; the port-limited Bus Opt designs higher.
    let eff = |opts: &OlympusOpts| {
        let spec = olympus::generate(&kernel, opts, &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        sim::simulate(&spec, &est, &platform, n).efficiency_vs_ideal
    };
    let base = eff(&OlympusOpts::baseline());
    let serial = eff(&OlympusOpts::bus_serial());
    assert!((0.3..0.75).contains(&base), "baseline eff {base}");
    assert!(serial > base, "bus-opt efficiency exceeds baseline");
    println!("shape checks passed: #Ops exact; Bus Opt efficiency > baseline\n");
}
