//! Fig. 19: comparison with software implementations across the three
//! kernels (Inverse Helmholtz, Interpolation, Gradient).
//!
//! Measured bars:
//!   * naive CPU     — hand-written single-thread loops (AMD EPYC analog),
//!     measured wall-clock on this machine;
//!   * XLA-CPU       — the `_ref` artifact through PJRT (Intel-MKL analog),
//!     measured wall-clock;
//!   * FPGA baseline / FPGA optimized — simulated on the U280 model.
//!
//! Absolute CPU numbers depend on this host; the *shape* (FPGA-opt >>
//! naive, FPGA-opt vs optimized-CPU, efficiency gap) is asserted.

use hbmflow::baselines::{measure_naive, measure_xla_ref};
use hbmflow::cli::build_kernel;
use hbmflow::coordinator::HelmholtzWorkload;
use hbmflow::hls;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::power::INTEL_XEON_AVG_W;
use hbmflow::platform::Platform;
use hbmflow::report::{self, paper};
use hbmflow::runtime::Runtime;
use hbmflow::sim;
use hbmflow::util::bench::section;

fn fpga(kernel_name: &str, opts: OlympusOpts, n: u64) -> sim::SimResult {
    let platform = Platform::alveo_u280();
    let p = if kernel_name == "gradient" { 8 } else { 11 };
    let kernel = build_kernel(kernel_name, p).unwrap();
    let spec = olympus::generate(&kernel, &opts, &platform).unwrap();
    let est = hls::estimate(&spec, &platform);
    sim::simulate(&spec, &est, &platform, n)
}

fn main() {
    section("Fig. 19a — kernels vs software implementations (double precision)");
    let n = paper::N_ELEMENTS;

    // --- measured CPU baselines (helmholtz) ---
    let w = HelmholtzWorkload::generate(11, 4096, 2024);
    let naive = measure_naive(&w, 1024);
    let xla = Runtime::from_default_dir()
        .ok()
        .and_then(|mut rt| measure_xla_ref(&mut rt, &w, 4096).ok());

    let mut rows = Vec::new();
    let mut opt_sys = std::collections::HashMap::new();
    for kname in ["helmholtz", "interpolation", "gradient"] {
        let base = fpga(kname, OlympusOpts::baseline(), n);
        // fully-optimized double config (paper: double buffering + bus
        // parallel + dataflow per loop nest)
        let groups = if kname == "helmholtz" { 7 } else { 3 };
        let opt = fpga(kname, OlympusOpts::dataflow(groups), n);
        opt_sys.insert(kname, opt.gflops_system);
        rows.push(vec![
            kname.to_string(),
            report::f(base.gflops_system),
            report::f(opt.gflops_system),
            if kname == "helmholtz" {
                report::f(naive.gflops)
            } else {
                "-".into()
            },
            if kname == "helmholtz" {
                xla.as_ref().map(|m| report::f(m.gflops)).unwrap_or("-".into())
            } else {
                "-".into()
            },
            report::f(paper::intel_optimized_gflops(kname)),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["kernel", "FPGA base", "FPGA opt", "naive CPU*", "XLA-CPU*", "Intel(paper)"],
            &rows
        )
    );
    println!("* measured on this machine, single PJRT CPU device\n");

    // --- shape checks ---
    let h_opt = opt_sys["helmholtz"];
    let speedup_naive = h_opt / naive.gflops;
    println!(
        "FPGA-opt / naive-CPU = {speedup_naive:.1}x (paper range {:.1}-{:.1}x \
         across kernels vs its EPYC host)",
        paper::FIG19.fpga_opt_over_naive.0, paper::FIG19.fpga_opt_over_naive.1
    );
    assert!(
        speedup_naive > 5.0,
        "optimized FPGA must dominate naive CPU"
    );
    let intel = paper::intel_optimized_gflops("helmholtz");
    let vs_intel = h_opt / intel;
    println!(
        "FPGA-opt / Intel-optimized(paper) = {vs_intel:.2}x (paper {:.1}x)",
        paper::FIG19.helmholtz_vs_intel
    );
    assert!((1.2..6.0).contains(&vs_intel));

    section("Fig. 19b — power and energy efficiency");
    let helm = fpga("helmholtz", OlympusOpts::dataflow(7), n);
    let fpga_eff = helm.efficiency_gflops_w;
    let intel_eff = intel / INTEL_XEON_AVG_W;
    let naive_eff = naive.gflops / naive.power_w;
    let mut prows = vec![
        vec![
            "FPGA optimized (double)".to_string(),
            report::f(helm.avg_power_w),
            format!("{:.3}", fpga_eff),
        ],
        vec![
            "Intel optimized (paper est.)".to_string(),
            report::f(INTEL_XEON_AVG_W),
            format!("{:.3}", intel_eff),
        ],
        vec![
            "naive CPU (measured)".to_string(),
            report::f(naive.power_w),
            format!("{:.3}", naive_eff),
        ],
    ];
    if let Some(x) = &xla {
        prows.push(vec![
            x.label.clone(),
            report::f(x.power_w),
            format!("{:.3}", x.gflops_per_w),
        ]);
    }
    println!(
        "{}",
        report::table(&["execution", "avg W", "GFLOPS/W"], &prows)
    );
    let eff_ratio = fpga_eff / intel_eff;
    println!(
        "efficiency: FPGA/Intel = {eff_ratio:.1}x (paper {:.1}x for double \
         Helmholtz; 24.5x for the fx32 build — see fig18_power)",
        paper::FIG19.efficiency_helmholtz
    );
    assert!(eff_ratio > 2.0, "FPGA must be multiples more efficient");
    println!("shape checks passed\n");
}
