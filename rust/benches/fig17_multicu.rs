//! Fig. 17 + Table 5: multi-CU replication (225 MHz target builds).
//!
//! The paper's key negative result: CU-only throughput scales with
//! replication but the *system* slows down because host transfers
//! serialize — "it is not recommended to replicate CUs until the host
//! data transfer time can be reduced."

use hbmflow::cli::build_kernel;
use hbmflow::datatype::DataType;
use hbmflow::hls;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::report::{self, paper};
use hbmflow::sim;
use hbmflow::util::bench::section;

fn main() {
    section("Fig. 17 / Table 5 — multi-CU replication");
    let platform = Platform::alveo_u280();
    let n = paper::N_ELEMENTS;

    // (dtype, p, CUs) per Table 5
    let cases: Vec<(DataType, usize, usize)> = vec![
        (DataType::F64, 11, 2),
        (DataType::F64, 7, 3),
        (DataType::Fx64, 11, 2),
        (DataType::Fx64, 7, 2),
        (DataType::Fx32, 11, 3),
        (DataType::Fx32, 7, 4),
    ];

    let mut rows = Vec::new();
    for (i, &(dtype, p, cus)) in cases.iter().enumerate() {
        let kernel = build_kernel("helmholtz", p).unwrap();
        let mk = |ncu: usize| {
            let mut o = if dtype.is_fixed() {
                OlympusOpts::fixed_point(dtype)
            } else {
                OlympusOpts::dataflow(7)
            };
            o = o.with_cus(ncu);
            let spec = olympus::generate(&kernel, &o, &platform).unwrap();
            let est = hls::estimate(&spec, &platform);
            let r = sim::simulate(&spec, &est, &platform, n);
            (est, r)
        };
        let (est1, one) = mk(1);
        let (est, multi) = mk(cus);
        let _ = est1;
        let pp = paper::TABLE5[i];
        rows.push(vec![
            format!("{} p={p} x{cus}", dtype.display()),
            report::f(multi.freq_mhz),
            report::f(pp.f_mhz),
            report::f(one.gflops_cu),
            report::f(multi.gflops_cu),
            report::f(multi.gflops_system),
            format!("{}", est.total.dsp),
            format!("{}", pp.dsp),
            multi.bottleneck.clone(),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["configuration", "f", "f(paper)", "CU(1)", "CU(n)", "System", "DSP", "DSP(paper)", "bound"],
            &rows
        )
    );

    // Headline shape: fx32 p=11 3 CUs — kernel scales, system collapses.
    let kernel = build_kernel("helmholtz", 11).unwrap();
    let run = |cus: usize| {
        let o = OlympusOpts::fixed_point(DataType::Fx32).with_cus(cus);
        let spec = olympus::generate(&kernel, &o, &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        sim::simulate(&spec, &est, &platform, n)
    };
    let one = run(1);
    let three = run(3);
    println!(
        "fx32 p=11: 1 CU kernel {:.1} -> 3 CU kernel {:.1} GOPS (paper {:.0});\n\
         3 CU system {:.1} GOPS (paper {:.0}) — bound by {}",
        one.gflops_cu,
        three.gflops_cu,
        paper::FIG17_FX32_P11_CU,
        three.gflops_system,
        paper::FIG17_FX32_P11_SYSTEM,
        three.bottleneck
    );
    assert!(three.gflops_cu > 1.3 * one.gflops_cu, "kernel must scale");
    assert!(
        three.gflops_system < three.gflops_cu / 1.3,
        "system must collapse (transfers serialize)"
    );
    assert_eq!(three.bottleneck, "pcie");
    // Frequency collapse for the double 2-CU build (Table 5: 199->146)
    let kernel_d = build_kernel("helmholtz", 11).unwrap();
    let f = |cus: usize| {
        let o = OlympusOpts::dataflow(7).with_cus(cus);
        let spec = olympus::generate(&kernel_d, &o, &platform).unwrap();
        hls::estimate(&spec, &platform).fmax_mhz
    };
    assert!(f(2) < f(1), "replication lowers frequency");
    println!("shape checks passed: kernel scales, system PCIe-bound, frequency collapses\n");
}
