//! Table 3: resource utilization per optimization (p = 11, 1 CU),
//! including the Mem Sharing and fixed-point rows.

use hbmflow::cli::build_kernel;
use hbmflow::datatype::DataType;
use hbmflow::hls;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::{Platform, Resources};
use hbmflow::report::{self, paper};
use hbmflow::util::bench::section;

fn row(
    kernel: &hbmflow::ir::affine::Kernel,
    platform: &Platform,
    opts: &OlympusOpts,
    p: &paper::ResourceRow,
) -> (Vec<String>, Resources) {
    let spec = olympus::generate(kernel, opts, platform).unwrap();
    let est = hls::estimate(&spec, platform);
    let budget = platform.total_resources();
    let u = est.total.utilization(&budget);
    let cells = vec![
        opts.label(),
        format!("{} ({:.1}%)", est.total.lut, u[0] * 100.0),
        format!("{}", p.lut),
        format!("{} ({:.1}%)", est.total.bram, u[2] * 100.0),
        format!("{}", p.bram),
        format!("{} ({:.1}%)", est.total.uram, u[3] * 100.0),
        format!("{}", p.uram),
        format!("{} ({:.1}%)", est.total.dsp, u[4] * 100.0),
        format!("{}", p.dsp),
    ];
    (cells, est.total)
}

fn main() {
    section("Table 3 — resource utilization (p=11, 1 CU); paper columns inline");
    let kernel = build_kernel("helmholtz", 11).unwrap();
    let platform = Platform::alveo_u280();

    let cases: Vec<(OlympusOpts, usize)> = vec![
        (OlympusOpts::baseline(), 0),
        (OlympusOpts::double_buffering(), 1),
        (OlympusOpts::bus_serial(), 2),
        (OlympusOpts::bus_parallel(), 3),
        (OlympusOpts::dataflow(1), 4),
        (OlympusOpts::dataflow(2), 5),
        (OlympusOpts::dataflow(3), 6),
        (OlympusOpts::dataflow(7), 7),
        (OlympusOpts::mem_sharing(), 8),
        (OlympusOpts::fixed_point(DataType::Fx64), 9),
        (OlympusOpts::fixed_point(DataType::Fx32), 10),
    ];

    let mut rows = Vec::new();
    let mut totals = Vec::new();
    for (opts, pi) in &cases {
        let (cells, total) = row(&kernel, &platform, opts, &paper::TABLE3[*pi]);
        rows.push(cells);
        totals.push(total);
    }
    println!(
        "{}",
        report::table(
            &["implementation", "LUT", "(paper)", "BRAM", "(paper)", "URAM", "(paper)", "DSP", "(paper)"],
            &rows
        )
    );

    // Shape checks the paper calls out.
    let dsp = |i: usize| totals[i].dsp as f64;
    assert!((dsp(9) - 4368.0).abs() / 4368.0 < 0.10, "fx64 DSP near paper");
    assert!(dsp(10) < dsp(9) * 0.6, "fx32 DSP ~half of fx64");
    assert!(totals[10].uram == 0, "fx32 URAM -> 0");
    assert!(totals[8].uram < totals[4].uram, "mem sharing cuts URAM");
    assert!(totals[8].dsp == totals[4].dsp, "sharing leaves datapath alone");
    let luts: Vec<u64> = [0usize, 4, 5, 7].iter().map(|&i| totals[i].lut).collect();
    assert!(luts.windows(2).all(|w| w[0] < w[1]), "LUT monotone up the ladder");
    println!("shape checks passed: fx DSP ratios, URAM->0, sharing saves URAM, LUT monotone\n");
}
