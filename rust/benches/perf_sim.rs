//! §Perf harness for the simulator itself: the BENCH trajectory
//! (started in PR 7, extended with the irregular-access grid in PR 10).
//!
//! Five sections, all recorded in `BENCH_10.json` at the repo root:
//!
//!  1. raw timeline schedulers — sequential vs parallel event timeline
//!     vs the closed-form analytic bracket on a synthetic million-batch
//!     workload (bit-identity and bracketing asserted in-line);
//!  2. per-point simulation cost over a kernel × CU-count × element
//!     grid — the event simulator (sequential baseline) against
//!     `sim::analytic`, with the bracket/gap contract asserted at every
//!     point;
//!  3. a dse sweep on a warm session — `Fidelity::Exact` against the
//!     default adaptive screen, the speedup the CLI's default
//!     `hbmflow dse` path actually delivers;
//!  4. the budget-aware streaming search (`--strategy stream`) on the
//!     same warm session — sweep throughput (points/sec) and the
//!     memory-boundedness witness (peak resident points vs candidates
//!     considered);
//!  5. the irregular-access grid — the gather/scatter builtins across
//!     cache schemes, with the traffic-model contracts (bracket holds,
//!     bypass strictly slower than the streaming-service FullBuffer)
//!     asserted at every point.
//!
//! Deterministic CI mode: `HBMFLOW_BENCH_ITERS=3 cargo bench --bench
//! perf_sim` (every `Bench` is constructed through `Bench::from_env`).
//! Output path: `HBMFLOW_BENCH_OUT` if set, else `../BENCH_10.json`
//! relative to the crate root. Every `BenchResult` is round-tripped
//! through `BenchResult::from_json(to_json())` before it is written, so
//! a serialization that drops a field aborts the run.

use std::time::Duration;

use hbmflow::dse::{self, Fidelity, SearchSpace};
use hbmflow::flow::{Flow, Session};
use hbmflow::hls;
use hbmflow::kernels::KernelSource;
use hbmflow::olympus::{BusMode, CacheScheme, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::report;
use hbmflow::sim::{self, analytic, event};
use hbmflow::util::bench::{fmt_dur, section, Bench, BenchResult};
use hbmflow::util::json::Json;

const DEFAULT_OUT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_10.json");
const KERNEL_DIR: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/kernels");

/// Short per-bench budget so the default (time-budget) mode finishes
/// the whole grid in seconds; `HBMFLOW_BENCH_ITERS` overrides it with
/// the fixed-iteration mode and ignores the budget entirely.
fn bench(name: String) -> Bench {
    Bench::from_env(name).budget(Duration::from_millis(80))
}

/// Round-trip guard: a result that cannot be decoded from its own
/// serialization must never reach the JSON file.
fn checked_json(r: &BenchResult) -> Json {
    let doc = r.to_json();
    let back = BenchResult::from_json(&doc)
        .unwrap_or_else(|e| panic!("bench result {:?} failed round-trip: {e}", r.name));
    assert_eq!(&back, r, "round-trip altered {:?}", r.name);
    doc
}

fn ns(d: Duration) -> f64 {
    d.as_nanos() as f64
}

/// Median-of-medians ratio helper (guards the zero-duration case the
/// analytic path can hit on a fast machine).
fn ratio(num: Duration, den: Duration) -> f64 {
    ns(num) / ns(den).max(1.0)
}

fn raw_timeline_section() -> Json {
    section("§Perf sim — raw timeline schedulers (1M batches, 8 CUs)");
    let cfg = event::TimelineConfig {
        n_batches: 1_000_000,
        n_cus: 8,
        t_in: 1.0e-6,
        t_batch: 6.0e-6,
        t_out: 1.0e-6,
        double_buffering: true,
    };
    let seq_tl = event::run_timeline_sequential(cfg);
    let par_tl = event::run_timeline_parallel(cfg, None);
    assert_eq!(
        seq_tl.total_s.to_bits(),
        par_tl.total_s.to_bits(),
        "parallel timeline must be bit-identical"
    );
    let b = analytic::bounds(&cfg);
    assert!(b.brackets(seq_tl.total_s), "analytic bracket failed: {b:?}");

    let seq = bench("timeline/sequential 1M×8".into())
        .run(|| event::run_timeline_sequential(cfg));
    let par = bench("timeline/parallel   1M×8".into())
        .run(|| event::run_timeline_parallel(cfg, None));
    let ana = bench("timeline/analytic   1M×8".into()).run(|| analytic::bounds(&cfg));
    for r in [&seq, &par, &ana] {
        println!("{}", r.report());
    }
    println!(
        "parallel speedup {:.2}x   analytic speedup {:.0}x   rel_gap {:.2e}",
        ratio(seq.median, par.median),
        ratio(seq.median, ana.median),
        b.rel_gap()
    );

    Json::obj(vec![
        ("n_batches", Json::num(cfg.n_batches as f64)),
        ("cus", Json::num(cfg.n_cus as f64)),
        ("rel_gap", Json::num(b.rel_gap())),
        ("sequential", checked_json(&seq)),
        ("parallel", checked_json(&par)),
        ("analytic", checked_json(&ana)),
        ("parallel_speedup", Json::num(ratio(seq.median, par.median))),
        ("analytic_speedup", Json::num(ratio(seq.median, ana.median))),
    ])
}

fn grid_section() -> (Json, Vec<f64>) {
    section("§Perf sim — per-point cost, kernel × CUs × elements grid");
    let platform = Platform::alveo_u280();
    let kernels: Vec<(String, KernelSource, usize)> = vec![
        ("helmholtz p11".into(), KernelSource::builtin("helmholtz"), 11),
        (
            "interpolation p11".into(),
            KernelSource::builtin("interpolation"),
            11,
        ),
        (
            "advect".into(),
            KernelSource::file(format!("{KERNEL_DIR}/advect.cfd")),
            0,
        ),
        (
            "stiffness".into(),
            KernelSource::file(format!("{KERNEL_DIR}/stiffness.cfd")),
            0,
        ),
    ];
    let mut points = Vec::new();
    let mut speedups = Vec::new();
    let mut rows = Vec::new();
    for (label, src, p) in &kernels {
        let lowered = Flow::from_source(src.clone())
            .parse(*p)
            .and_then(|pa| pa.lower())
            .unwrap_or_else(|e| panic!("{label}: {e}"));
        let groups = lowered.kernel.nests.len().clamp(1, 7);
        for cus in [1usize, 4, 8] {
            let opts = OlympusOpts::dataflow(groups).with_cus(cus);
            let mapped = match lowered.map(&opts, &platform) {
                Ok(m) => m,
                // some (kernel, CU) corners exceed the channel budget;
                // the grid records what the platform can host
                Err(e) => {
                    println!("skip {label} × {cus} CUs: {e}");
                    continue;
                }
            };
            let est = hls::estimate(&mapped.spec, &platform);
            for elements in [500_000u64, 2_000_000, 8_000_000] {
                let ev = sim::simulate_with_timeline(
                    &mapped.spec,
                    &est,
                    &platform,
                    elements,
                    event::TimelineMode::Sequential,
                );
                let an = analytic::simulate_analytic(&mapped.spec, &est, &platform, elements);
                let b = an.analytic.expect("analytic result carries its bracket");
                assert!(
                    b.brackets(ev.total_time_s),
                    "{label} × {cus} CUs × {elements}: bracket failed ({b:?} vs {})",
                    ev.total_time_s
                );
                let contract = (cus as f64 + 1.0) / ev.batches.max(1) as f64 + 1e-6;
                assert!(
                    b.rel_gap() <= contract,
                    "{label} × {cus} CUs × {elements}: gap {} > contract {contract}",
                    b.rel_gap()
                );

                let name = format!("{label} × {cus}cu × {}k", elements / 1000);
                let seq = bench(format!("event {name}")).run(|| {
                    sim::simulate_with_timeline(
                        &mapped.spec,
                        &est,
                        &platform,
                        elements,
                        event::TimelineMode::Sequential,
                    )
                });
                let ana = bench(format!("analytic {name}")).run(|| {
                    analytic::simulate_analytic(&mapped.spec, &est, &platform, elements)
                });
                let sp = ratio(seq.median, ana.median);
                speedups.push(sp);
                rows.push(vec![
                    name.clone(),
                    format!("{}", ev.batches),
                    fmt_dur(seq.median),
                    fmt_dur(ana.median),
                    format!("{sp:.1}x"),
                    format!("{:.2e}", b.rel_gap()),
                ]);
                points.push(Json::obj(vec![
                    ("kernel", Json::str(label.as_str())),
                    ("cus", Json::num(cus as f64)),
                    ("elements", Json::num(elements as f64)),
                    ("n_batches", Json::num(ev.batches as f64)),
                    ("rel_gap", Json::num(b.rel_gap())),
                    ("event_seq", checked_json(&seq)),
                    ("analytic", checked_json(&ana)),
                    ("analytic_speedup", Json::num(sp)),
                ]));
            }
        }
    }
    println!(
        "{}",
        report::table(
            &["point", "batches", "event med", "analytic med", "speedup", "rel_gap"],
            &rows
        )
    );
    (Json::Arr(points), speedups)
}

fn dse_section() -> Json {
    section("§Perf sim — dse sweep, adaptive screen vs exact fidelity");
    let mut space = SearchSpace::default_for("helmholtz");
    space.degrees = vec![11];
    space.cu_counts = vec![1, 2, 3];
    space.dataflow = vec![Some(7)];
    space.double_buffering = vec![true];
    space.bus_modes = vec![BusMode::Wide256Parallel];
    space.fifo_depths = vec![None];
    let n_points = space.enumerate().len();
    let elements = 8_000_000u64;

    // warm session: parse/lower/map/estimate artifacts are shared by
    // both fidelities, so the measured difference below is the sim +
    // frontier work — the phase this PR makes fast
    let session = Session::new(Platform::alveo_u280());
    let warm = dse::explore_in_with(&session, &space, elements, Some(1), Fidelity::Exact)
        .expect("warm sweep");
    let adaptive = dse::explore_in(&session, &space, elements, Some(1)).expect("adaptive");
    assert_eq!(
        warm.frontier, adaptive.frontier,
        "adaptive screen must reproduce the exact frontier"
    );

    let exact_b = bench(format!("dse exact    ({n_points} pts)")).run(|| {
        dse::explore_in_with(&session, &space, elements, Some(1), Fidelity::Exact).unwrap()
    });
    let adapt_b = bench(format!("dse adaptive ({n_points} pts)")).run(|| {
        dse::explore_in(&session, &space, elements, Some(1)).unwrap()
    });
    for r in [&exact_b, &adapt_b] {
        println!("{}", r.report());
    }
    let sp = ratio(exact_b.median, adapt_b.median);
    println!(
        "adaptive sweep speedup {sp:.2}x over exact ({} vs {} per point)",
        fmt_dur(exact_b.median / n_points.max(1) as u32),
        fmt_dur(adapt_b.median / n_points.max(1) as u32),
    );

    Json::obj(vec![
        ("kernel", Json::str("helmholtz")),
        ("space_points", Json::num(n_points as f64)),
        ("elements", Json::num(elements as f64)),
        ("exact", checked_json(&exact_b)),
        ("adaptive", checked_json(&adapt_b)),
        ("adaptive_speedup", Json::num(sp)),
    ])
}

fn search_section() -> Json {
    section("§Perf sim — budget-aware streaming search (dse --strategy stream)");
    let mut space = SearchSpace::default_for("helmholtz");
    space.degrees = vec![11];
    space.cu_counts = vec![1, 2, 3];
    space.dataflow = vec![Some(2), Some(7)];
    space.double_buffering = vec![true];
    space.bus_modes = vec![BusMode::Wide256Parallel];
    space.fifo_depths = vec![None];
    let elements = 8_000_000u64;

    // warm session, like dse_section: the measured work is the stream
    // (analytic screen + surviving sims + incremental frontier), not
    // parse/lower/map
    let session = Session::new(Platform::alveo_u280());
    let cfg = dse::SearchConfig {
        batch: 8,
        threads: Some(1),
        ..dse::SearchConfig::default()
    };
    let warm = dse::search_in(&session, &space, elements, &cfg).expect("stream sweep");
    let stats = warm.stats.expect("search results carry stats");
    assert!(stats.complete, "the stream must drain the space");

    let stream_b = bench(format!("dse stream   ({} pts)", stats.considered))
        .run(|| dse::search_in(&session, &space, elements, &cfg).unwrap());
    println!("{}", stream_b.report());
    let points_per_sec = stats.considered as f64 / (ns(stream_b.median) / 1e9).max(1e-12);
    println!(
        "stream sweep: {} considered, {} pruned, peak resident {} \
         (frontier peak {}), {points_per_sec:.0} points/s",
        stats.considered, stats.pruned, stats.peak_resident, stats.frontier_peak
    );

    Json::obj(vec![
        ("kernel", Json::str("helmholtz")),
        ("strategy", Json::str("stream")),
        ("batch", Json::num(cfg.batch as f64)),
        ("elements", Json::num(elements as f64)),
        ("considered", Json::num(stats.considered as f64)),
        ("pruned", Json::num(stats.pruned as f64)),
        ("frontier", Json::num(warm.frontier.len() as f64)),
        ("peak_resident_points", Json::num(stats.peak_resident as f64)),
        ("frontier_peak", Json::num(stats.frontier_peak as f64)),
        ("stream", checked_json(&stream_b)),
        ("points_per_sec", Json::num(points_per_sec)),
    ])
}

fn irregular_section() -> Json {
    section("§Perf sim — irregular access, gather/scatter × cache scheme");
    let platform = Platform::alveo_u280();
    let elements = 1_000_000u64;
    let mut points = Vec::new();
    let mut rows = Vec::new();
    for name in ["mesh_gather", "scatter_assembly"] {
        let lowered = Flow::from_source(KernelSource::builtin(name))
            .parse(0)
            .and_then(|pa| pa.lower())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut bypass_time = None;
        for scheme in [
            CacheScheme::Bypass,
            CacheScheme::Cached(128),
            CacheScheme::FullBuffer,
        ] {
            // flat baseline: the memory-bound shape where the traffic
            // model is the binding term
            let opts = OlympusOpts::baseline().with_cache_scheme(scheme);
            let mapped = lowered.map(&opts, &platform).unwrap_or_else(|e| {
                panic!("{name} × {scheme:?}: {e}")
            });
            let est = hls::estimate(&mapped.spec, &platform);
            let ev = sim::simulate_with_timeline(
                &mapped.spec,
                &est,
                &platform,
                elements,
                event::TimelineMode::Sequential,
            );
            let an = analytic::simulate_analytic(&mapped.spec, &est, &platform, elements);
            let b = an.analytic.expect("analytic result carries its bracket");
            assert!(
                b.brackets(ev.total_time_s),
                "{name} × {scheme:?}: bracket failed ({b:?} vs {})",
                ev.total_time_s
            );
            match scheme {
                // FullBuffer is the streaming-service equivalent: the
                // uncached gather/scatter must be strictly slower
                CacheScheme::Bypass => bypass_time = Some(ev.total_time_s),
                CacheScheme::FullBuffer => assert!(
                    bypass_time.is_some_and(|t| t > ev.total_time_s),
                    "{name}: bypass {:?} not slower than full {}",
                    bypass_time,
                    ev.total_time_s
                ),
                CacheScheme::Cached(_) => {}
            }

            let label = format!("{name} × {scheme:?}");
            let seq = bench(format!("event {label}")).run(|| {
                sim::simulate_with_timeline(
                    &mapped.spec,
                    &est,
                    &platform,
                    elements,
                    event::TimelineMode::Sequential,
                )
            });
            let ana = bench(format!("analytic {label}")).run(|| {
                analytic::simulate_analytic(&mapped.spec, &est, &platform, elements)
            });
            rows.push(vec![
                label.clone(),
                format!("{:.4}", ev.total_time_s),
                fmt_dur(seq.median),
                fmt_dur(ana.median),
                format!("{:.2e}", b.rel_gap()),
            ]);
            points.push(Json::obj(vec![
                ("kernel", Json::str(name)),
                ("scheme", Json::str(scheme.name().as_str())),
                ("elements", Json::num(elements as f64)),
                ("makespan_s", Json::num(ev.total_time_s)),
                ("rel_gap", Json::num(b.rel_gap())),
                ("event_seq", checked_json(&seq)),
                ("analytic", checked_json(&ana)),
            ]));
        }
    }
    println!(
        "{}",
        report::table(
            &["point", "makespan", "event med", "analytic med", "rel_gap"],
            &rows
        )
    );
    Json::Arr(points)
}

fn main() {
    let fixed_iters = std::env::var("HBMFLOW_BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&k| k > 0);

    let raw = raw_timeline_section();
    let (points, speedups) = grid_section();
    let dse = dse_section();
    let search = search_section();
    let irregular = irregular_section();

    let mut sorted = speedups.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let median_speedup = if sorted.is_empty() {
        0.0
    } else {
        sorted[sorted.len() / 2]
    };

    let doc = Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("bench", Json::str("perf_sim")),
        ("pr", Json::num(10.0)),
        (
            "fixed_iters",
            fixed_iters.map_or(Json::Null, |k| Json::num(k as f64)),
        ),
        ("timeline_raw", raw),
        ("points", points),
        ("dse", dse),
        ("search", search),
        ("irregular", irregular),
        (
            "summary",
            Json::obj(vec![(
                "median_analytic_speedup",
                Json::num(median_speedup),
            )]),
        ),
    ]);

    let out = std::env::var("HBMFLOW_BENCH_OUT").unwrap_or_else(|_| DEFAULT_OUT.into());
    std::fs::write(&out, format!("{doc}\n"))
        .unwrap_or_else(|e| panic!("writing {out}: {e}"));
    println!("\nwrote {out} (median per-point analytic speedup {median_speedup:.1}x)");
}
