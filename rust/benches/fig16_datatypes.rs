//! Fig. 16 + Table 4: data representation x polynomial degree
//! (Dataflow-7, 1 CU) — performance, resources, and the §4.2 fixed-point
//! MSE measured through the real PJRT artifacts (E9 in DESIGN.md).

use hbmflow::cli::build_kernel;
use hbmflow::coordinator::{Driver, HelmholtzWorkload};
use hbmflow::datatype::DataType;
use hbmflow::hls;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::report::{self, paper};
use hbmflow::runtime::Runtime;
use hbmflow::sim;
use hbmflow::util::bench::section;

fn main() {
    section("Fig. 16 / Table 4 — data representation x p (Dataflow-7, 1 CU)");
    let platform = Platform::alveo_u280();
    let n = paper::N_ELEMENTS;

    let mut rows = Vec::new();
    let mut sys = std::collections::HashMap::new();
    for p in [11usize, 7] {
        let kernel = build_kernel("helmholtz", p).unwrap();
        for dtype in [DataType::F64, DataType::Fx64, DataType::Fx32] {
            let opts = if dtype.is_fixed() {
                OlympusOpts::fixed_point(dtype)
            } else {
                OlympusOpts::dataflow(7)
            };
            let spec = olympus::generate(&kernel, &opts, &platform).unwrap();
            let est = hls::estimate(&spec, &platform);
            let r = sim::simulate(&spec, &est, &platform, n);
            let pg = paper::fig16_gflops(dtype.name(), p);
            sys.insert((dtype.name(), p), r.gflops_system);
            rows.push(vec![
                format!("{} p={p}", dtype.display()),
                report::f(r.gflops_cu),
                report::f(r.gflops_system),
                report::f(pg),
                report::f(r.freq_mhz),
                format!("{}", est.total.dsp),
                format!("{}", est.total.uram),
            ]);
        }
    }
    println!(
        "{}",
        report::table(
            &["configuration", "CU", "System", "paper", "f(MHz)", "DSP", "URAM"],
            &rows
        )
    );

    // Fig. 16 shape: fx64 ~1.19x, fx32 ~2.37x over double at p=11;
    // p=7 slightly slower than its p=11 counterpart.
    let g = |d: &str, p: usize| sys[&(d, p)];
    let r64 = g("fx64", 11) / g("f64", 11);
    let r32 = g("fx32", 11) / g("f64", 11);
    assert!((1.0..1.6).contains(&r64), "fx64/double {r64} (paper 1.19)");
    assert!((1.7..3.2).contains(&r32), "fx32/double {r32} (paper 2.37)");
    for d in ["f64", "fx64", "fx32"] {
        assert!(g(d, 7) < g(d, 11), "{d}: p=7 slightly slower (paper Fig. 16)");
    }
    println!(
        "shape checks passed: fx64 x{r64:.2}, fx32 x{r32:.2} over double (paper 1.19 / 2.37)\n"
    );

    // E9: measured fixed-point MSE through the real artifacts.
    section("§4.2 fixed-point MSE (measured through PJRT artifacts)");
    match Runtime::from_default_dir() {
        Ok(mut rt) => {
            let w = HelmholtzWorkload::generate(11, 64, 99);
            let mut mse_rows = Vec::new();
            let mut measured = std::collections::HashMap::new();
            for (dtype, paper_mse) in [
                (DataType::Fx64, paper::MSE_FX64),
                (DataType::Fx32, paper::MSE_FX32),
            ] {
                let kernel = build_kernel("helmholtz", 11).unwrap();
                let spec = olympus::generate(
                    &kernel,
                    &OlympusOpts::fixed_point(dtype),
                    &platform,
                )
                .unwrap();
                let artifact = Driver::artifact_for(&rt, &spec, 11).unwrap();
                let mut d = Driver::new(&mut rt, spec, artifact);
                let r = d.run(&w, 32).unwrap();
                measured.insert(dtype.name(), r.mse_vs_oracle);
                mse_rows.push(vec![
                    dtype.display().to_string(),
                    format!("{:.3e}", r.mse_vs_oracle),
                    format!("{paper_mse:.3e}"),
                ]);
            }
            println!(
                "{}",
                report::table(&["format", "measured MSE", "paper MSE"], &mse_rows)
            );
            let ratio = measured["fx32"] / measured["fx64"];
            assert!(
                ratio > 1e6,
                "MSE(fx32)/MSE(fx64) must be ~2^32-ish, got {ratio}"
            );
            println!(
                "shape check passed: MSE ratio fx32/fx64 = {ratio:.2e} (paper 3.8e9). \
                 Absolute MSEs are below the paper's because fake quantization \
                 rounds at operator granularity (see DESIGN.md).\n"
            );
        }
        Err(e) => println!("skipping MSE measurement (artifacts missing: {e})\n"),
    }
}
