//! §Perf harness: measured throughput of the three layers' hot paths.
//!
//! L1/L2 — XLA-CPU execution of the AOT artifacts:
//!     pallas (per-element grid)  vs  pallas_blocked (batched GEMMs)
//!     vs  ref (pure-jnp fused oracle). Target: blocked >= 0.5x ref.
//! L3 — the coordinator driver (interleave + dispatch) and the system
//!     simulator + generator.
//!
//! Results are recorded in EXPERIMENTS.md §Perf.

use std::time::Instant;

use hbmflow::cli::build_kernel;
use hbmflow::coordinator::{Driver, HelmholtzWorkload};
use hbmflow::hls;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::report;
use hbmflow::runtime::Runtime;
use hbmflow::sim;
use hbmflow::util::bench::{section, Bench};
use hbmflow::util::prng::Prng;

fn measure_artifact(rt: &mut Runtime, name: &str, n_elements: usize) -> Option<f64> {
    let meta = rt.meta(name)?.clone();
    let (p, b) = (meta.p, meta.batch);
    let block = p * p * p;
    let mut rng = Prng::new(1);
    let mut s = rng.unit_vec(p * p);
    for x in &mut s {
        *x /= p as f64;
    }
    let d = rng.unit_vec(b * block);
    let u = rng.unit_vec(b * block);
    // warmup: compile + one run
    rt.run_f64(name, &[s.clone(), d.clone(), u.clone()]).ok()?;
    let iters = n_elements.div_ceil(b);
    let t0 = Instant::now();
    for _ in 0..iters {
        let out = rt
            .run_f64(name, &[s.clone(), d.clone(), u.clone()])
            .ok()?;
        std::hint::black_box(&out);
    }
    let wall = t0.elapsed().as_secs_f64();
    let flops = (iters * b) as u64 * meta.flops_per_element;
    Some(flops as f64 / wall / 1e9)
}

fn main() {
    section("§Perf L1/L2 — datapath variants through PJRT (p=11, f64)");
    let mut rt = match Runtime::from_default_dir() {
        Ok(rt) => rt,
        Err(e) => {
            println!("artifacts missing: {e}");
            return;
        }
    };
    let n = 2048;
    let mut rows = Vec::new();
    let mut meas = std::collections::HashMap::new();
    for (label, artifact) in [
        ("pallas per-element grid", "helmholtz_p11_f64_b32"),
        ("pallas batch-blocked", "helmholtz_p11_f64_b32_pallas_blocked"),
        ("pure-jnp ref (oracle)", "helmholtz_p11_f64_b32_ref"),
    ] {
        if let Some(g) = measure_artifact(&mut rt, artifact, n) {
            meas.insert(label, g);
            rows.push(vec![label.to_string(), report::f(g)]);
        }
    }
    println!("{}", report::table(&["datapath", "GFLOPS"], &rows));
    if let (Some(&grid), Some(&blocked), Some(&refv)) = (
        meas.get("pallas per-element grid"),
        meas.get("pallas batch-blocked"),
        meas.get("pure-jnp ref (oracle)"),
    ) {
        println!(
            "blocked / grid = {:.2}x   blocked / ref = {:.2}x (target >= 0.5x)\n",
            blocked / grid,
            blocked / refv
        );
        assert!(blocked > grid, "blocking must help");
        assert!(blocked / refv >= 0.5, "blocked must reach half of ref");
    }

    section("§Perf L1/L2 — fx32 blocked variant");
    let mut rows = Vec::new();
    for (label, artifact) in [
        ("fx32 per-element grid", "helmholtz_p11_fx32_b32"),
        ("fx32 batch-blocked", "helmholtz_p11_fx32_b32_pallas_blocked"),
    ] {
        if let Some(g) = measure_artifact(&mut rt, artifact, n) {
            rows.push(vec![label.to_string(), report::f(g)]);
        }
    }
    println!("{}", report::table(&["datapath", "GOPS (emulated)"], &rows));

    section("§Perf L3 — coordinator driver wall time (1024 elements, p=11)");
    {
        let kernel = build_kernel("helmholtz", 11).unwrap();
        let platform = Platform::alveo_u280();
        let spec =
            olympus::generate(&kernel, &OlympusOpts::dataflow(7), &platform).unwrap();
        let w = HelmholtzWorkload::generate(11, 1024, 3);
        for artifact in [
            "helmholtz_p11_f64_b32",
            "helmholtz_p11_f64_b32_pallas_blocked",
        ] {
            if rt.meta(artifact).is_none() {
                continue;
            }
            rt.load(artifact).unwrap(); // exclude XLA compile time
            let mut driver = Driver::new(&mut rt, spec.clone(), artifact);
            driver.run(&w, 0).unwrap(); // warm run
            let r1 = driver.run(&w, 0).unwrap();
            let r2 = driver.run(&w, 0).unwrap();
            let best = if r1.wall_s < r2.wall_s { &r1 } else { &r2 };
            println!(
                "driver[{artifact}]: {:.3} s wall, {:.2} GFLOPS end-to-end",
                best.wall_s, best.measured_gflops
            );
        }
    }

    section("§Perf L3 — simulator and generator");
    {
        let kernel = build_kernel("helmholtz", 11).unwrap();
        let platform = Platform::alveo_u280();
        let spec =
            olympus::generate(&kernel, &OlympusOpts::dataflow(7), &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        let b = Bench::new("sim::simulate (N_eq = 2M)")
            .run(|| sim::simulate(&spec, &est, &platform, 2_000_000));
        println!("{}", b.report());
        let b = Bench::new("full pipeline: parse -> ... -> estimate").run(|| {
            let k = build_kernel("helmholtz", 11).unwrap();
            let s = olympus::generate(&k, &OlympusOpts::dataflow(7), &platform).unwrap();
            hls::estimate(&s, &platform)
        });
        println!("{}", b.report());
    }
}
