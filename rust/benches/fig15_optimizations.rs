//! Fig. 15: performance of each optimization, 1 CU, p = 11, N_eq = 2M.
//!
//! Regenerates the CU-vs-System GFLOPS bars for the full optimization
//! ladder, printing measured vs paper. Also times the simulator itself
//! (the L3 hot path of this repo).

use hbmflow::cli::build_kernel;
use hbmflow::hls;
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::report::{self, paper};
use hbmflow::sim;
use hbmflow::util::bench::{section, Bench};

fn main() {
    section("Fig. 15 — performance per optimization (1 CU, p=11, N_eq=2M)");
    let kernel = build_kernel("helmholtz", 11).unwrap();
    let platform = Platform::alveo_u280();
    let n = paper::N_ELEMENTS;

    let ladder: Vec<OlympusOpts> = vec![
        OlympusOpts::baseline(),
        OlympusOpts::double_buffering(),
        OlympusOpts::bus_serial(),
        OlympusOpts::bus_parallel(),
        OlympusOpts::dataflow(1),
        OlympusOpts::dataflow(2),
        OlympusOpts::dataflow(3),
        OlympusOpts::dataflow(7),
    ];

    let mut rows = Vec::new();
    for (i, opts) in ladder.iter().enumerate() {
        let spec = olympus::generate(&kernel, opts, &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        let r = sim::simulate(&spec, &est, &platform, n);
        let p = paper::TABLE2[i];
        rows.push(vec![
            opts.label(),
            report::f(r.gflops_cu),
            report::f(r.gflops_system),
            report::f(p.gflops),
            format!("{:.2}", r.gflops_system / p.gflops),
            report::f(r.freq_mhz),
            report::f(p.f_mhz),
        ]);
    }
    println!(
        "{}",
        report::table(
            &["implementation", "CU", "System", "paper", "ratio", "f", "f(paper)"],
            &rows
        )
    );

    // shape assertions (who wins, by what factor)
    let g = |i: usize| -> f64 {
        let spec = olympus::generate(&kernel, &ladder[i], &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        sim::simulate(&spec, &est, &platform, n).gflops_system
    };
    assert!(g(2) < g(1) / 2.0, "bus serial must degrade >=2x");
    assert!(g(3) / g(2) > 3.0, "parallel recovers ~3.9x over serial");
    assert!(g(4) > 2.5 * g(3), "dataflow-1 ~3.7x over parallel");
    assert!(g(6) <= 1.05 * g(5), "dataflow-3 no better than dataflow-2");
    assert!(g(7) > g(5), "dataflow-7 is the best double variant");
    println!("shape checks passed: serial degrades, parallel recovers, DF3<=DF2, DF7 best\n");

    // L3 hot-path timing: one full ladder simulation
    let spec = olympus::generate(&kernel, &ladder[7], &platform).unwrap();
    let est = hls::estimate(&spec, &platform);
    let b = Bench::new("simulate 2M elements (dataflow-7)")
        .run(|| sim::simulate(&spec, &est, &platform, n));
    println!("{}", b.report());
    let b2 = Bench::new("olympus generate + hls estimate")
        .run(|| {
            let s = olympus::generate(&kernel, &ladder[7], &platform).unwrap();
            hls::estimate(&s, &platform)
        });
    println!("{}", b2.report());
}
