//! Ablations for the design choices DESIGN.md calls out (not a paper
//! table; referenced in the paper's discussion sections):
//!
//!  1. HBM vs DDR4 global memory            (§2.3 Challenge discussion)
//!  2. FIFO sizing: naive full-size vs reduced      (§4.2 multi-CU prep)
//!  3. Mnemosyne memory sharing on/off               (§3.6.4, Table 3)
//!  4. PCIe effective-bandwidth sensitivity          (§4.2 Fig. 17 root cause)
//!  5. Multi-FPGA scaling what-if                    (§5 conclusion)
//!  6. Fixed-point format exploration (base2 DSE)    (§3.4.5 future work)

use hbmflow::cli::build_kernel;
use hbmflow::datatype::DataType;
use hbmflow::hls;
use hbmflow::ir::{rewrite, teil};
use hbmflow::olympus::{self, OlympusOpts};
use hbmflow::platform::Platform;
use hbmflow::precision::{self, Interval};
use hbmflow::report::{self, paper};
use hbmflow::sim;
use hbmflow::util::bench::section;

fn main() {
    let n = paper::N_ELEMENTS;
    let platform = Platform::alveo_u280();
    let kernel = build_kernel("helmholtz", 11).unwrap();

    // ---- 1. HBM vs DDR4 ----
    section("Ablation 1 — HBM vs DDR4 global memory");
    {
        let mut rows = Vec::new();
        let mut best = std::collections::HashMap::new();
        for (label, opts) in [
            ("HBM, dataflow-7, 1 CU", OlympusOpts::dataflow(7)),
            ("HBM, fx32, 1 CU", OlympusOpts::fixed_point(DataType::Fx32)),
            ("DDR4, dataflow-7, 1 CU", OlympusOpts::dataflow(7).on_ddr4()),
            (
                "DDR4, baseline x2 (bank limit)",
                OlympusOpts::baseline().on_ddr4().with_cus(2),
            ),
        ] {
            let spec = olympus::generate(&kernel, &opts, &platform).unwrap();
            let est = hls::estimate(&spec, &platform);
            let r = sim::simulate(&spec, &est, &platform, n);
            best.insert(label, r.gflops_system);
            rows.push(vec![
                label.to_string(),
                format!("{}", spec.total_pcs()),
                report::f(r.gflops_system),
                r.bottleneck.clone(),
            ]);
        }
        println!(
            "{}",
            report::table(&["configuration", "channels", "System", "bound"], &rows)
        );
        assert!(
            best["HBM, dataflow-7, 1 CU"] > best["DDR4, baseline x2 (bank limit)"],
            "HBM's channel parallelism must beat the two DDR banks"
        );
        println!("check passed: HBM channel parallelism > DDR4's two banks\n");
    }

    // ---- 2. FIFO sizing ----
    section("Ablation 2 — stream FIFO sizing (BRAM vs throughput)");
    {
        let mut rows = Vec::new();
        let mut brams = Vec::new();
        for (label, depth) in [("full (naive)", None), ("256 words", Some(256)), ("64 words", Some(64))] {
            let mut opts = OlympusOpts::dataflow(7);
            opts.fifo_depth = depth;
            let spec = olympus::generate(&kernel, &opts, &platform).unwrap();
            let est = hls::estimate(&spec, &platform);
            let r = sim::simulate(&spec, &est, &platform, n);
            brams.push(est.total.bram);
            rows.push(vec![
                label.to_string(),
                format!("{}", est.total.bram),
                report::f(r.gflops_system),
            ]);
        }
        println!("{}", report::table(&["FIFO depth", "BRAM", "System"], &rows));
        assert!(brams[2] < brams[0], "smaller FIFOs must save BRAM");
        println!("check passed: reduced FIFOs save BRAM (paper's multi-CU prep)\n");
    }

    // ---- 3. Memory sharing ----
    section("Ablation 3 — Mnemosyne sharing on the 1-compute dataflow");
    {
        let no = {
            let spec =
                olympus::generate(&kernel, &OlympusOpts::dataflow(1), &platform).unwrap();
            hls::estimate(&spec, &platform)
        };
        let yes = {
            let spec =
                olympus::generate(&kernel, &OlympusOpts::mem_sharing(), &platform).unwrap();
            hls::estimate(&spec, &platform)
        };
        println!(
            "URAM {} -> {} ({:+.1}%)   BRAM {} -> {}   DSP {} -> {} (unchanged)",
            no.total.uram,
            yes.total.uram,
            (yes.total.uram as f64 / no.total.uram as f64 - 1.0) * 100.0,
            no.total.bram,
            yes.total.bram,
            no.total.dsp,
            yes.total.dsp,
        );
        assert!(yes.total.uram < no.total.uram);
        assert_eq!(yes.total.dsp, no.total.dsp);
        println!("check passed: sharing trades nothing on the datapath (paper -48% URAM)\n");
    }

    // ---- 4. PCIe bandwidth sensitivity ----
    section("Ablation 4 — PCIe effective bandwidth vs multi-CU payoff");
    {
        let opts = OlympusOpts::fixed_point(DataType::Fx32).with_cus(3);
        let spec = olympus::generate(&kernel, &opts, &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        let mut rows = Vec::new();
        let mut sys = Vec::new();
        for bw in [4.0e9, 7.0e9, 12.0e9, 16.0e9, 32.0e9] {
            let mut pf = platform.clone();
            pf.pcie_eff_bytes_per_sec = bw;
            let r = sim::simulate(&spec, &est, &pf, n);
            sys.push(r.gflops_system);
            rows.push(vec![
                format!("{:.0} GB/s", bw / 1e9),
                report::f(r.gflops_system),
                report::f(r.gflops_cu),
                r.bottleneck.clone(),
            ]);
        }
        println!(
            "{}",
            report::table(&["PCIe eff.", "System", "CU", "bound"], &rows)
        );
        assert!(sys.windows(2).all(|w| w[1] >= w[0] * 0.999));
        assert!(
            sys[4] > 1.3 * sys[1],
            "faster host link must unlock the replicated CUs"
        );
        println!(
            "check passed: replication pays only once the host link scales — \
             the paper's Fig. 17 conclusion\n"
        );
    }

    // ---- 5. Multi-FPGA what-if ----
    section("Ablation 5 — multi-FPGA scaling (paper §5 what-if)");
    {
        let opts = OlympusOpts::fixed_point(DataType::Fx32);
        let spec = olympus::generate(&kernel, &opts, &platform).unwrap();
        let est = hls::estimate(&spec, &platform);
        let mut rows = Vec::new();
        let mut sys = Vec::new();
        for cards in [1u64, 2, 4, 8] {
            let r = sim::simulate_multi_fpga(&spec, &est, &platform, n, cards);
            sys.push(r.gflops_system);
            rows.push(vec![
                format!("{cards} card(s)"),
                report::f(r.gflops_system),
                format!("{:.2}x", r.gflops_system / sys[0]),
            ]);
        }
        println!("{}", report::table(&["FPGAs", "System", "scaling"], &rows));
        assert!(sys[2] / sys[0] > 3.0, "4 cards ~4x");
        println!("check passed: per-card PCIe links restore replication scaling\n");
    }

    // ---- 6. Precision exploration ----
    section("Ablation 6 — fixed-point format exploration (base2 DSE)");
    {
        let prog = hbmflow::dsl::parse(&hbmflow::dsl::inverse_helmholtz_source(11)).unwrap();
        let module = rewrite::optimize(teil::from_ast(&prog).unwrap());
        let mut rows = Vec::new();
        for (label, budget) in [
            ("paper fx32 budget (3.6e-12)", 3.6e-12),
            ("tight (1e-18)", 1e-18),
            ("paper fx64 budget (9.4e-22)", 9.4e-22),
        ] {
            let cands =
                precision::explore(&module, Interval::symmetric(1.0 / 11.0), budget, 64);
            let best = cands.first();
            rows.push(vec![
                label.to_string(),
                best.map(|c| c.name()).unwrap_or_else(|| "-".into()),
                best.map(|c| format!("{:.1e}", c.predicted_mse)).unwrap_or_default(),
                best.map(|c| format!("{}", c.dsp_per_mult)).unwrap_or_default(),
                format!("{}", cands.len()),
            ]);
        }
        println!(
            "{}",
            report::table(
                &["error budget", "cheapest format", "pred. MSE", "DSP/mult", "#feasible"],
                &rows
            )
        );
        let loose =
            precision::explore(&module, Interval::symmetric(1.0 / 11.0), 3.6e-12, 64);
        let tight =
            precision::explore(&module, Interval::symmetric(1.0 / 11.0), 9.4e-22, 64);
        assert!(loose[0].total_bits() < tight[0].total_bits());
        println!(
            "check passed: looser error budgets admit narrower (cheaper) formats — \
             the DSE the paper defers to the designer\n"
        );
    }
}
